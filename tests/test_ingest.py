"""Real-sensor ingest: backend protocol conformance, tool-output
parsing (declared wrap/resolution semantics), prioritized fallback with
error budgets and last-good caching, the async pump's ingest-boundary
dedupe, and the live capture path surviving a mid-run backend kill."""
import dataclasses
import json
import time

import numpy as np
import pytest

from repro.core import ToolSpec, simulate_sensor, square_wave
from repro.core.measurement_model import SensorSpec
from repro.core.reconstruction import unwrap_counter
from repro.core.sensors import SensorTrace
from repro.health.events import HEALTHY, QUARANTINED
from repro.health.registry import HealthRegistry
from repro.ingest import (AsyncFleetIngest, BackendError, BackendReader,
                          HwmonBackend, IngestPolicy, IngestUnavailable,
                          MetricSpec, PrioritizedIngest, RaplBackend,
                          Reading, RocmSmiBackend, SensorBackend,
                          SimBackend, SimulatedSMIReader, attribute_live,
                          default_backend_order)
from repro.ingest.rocm import (ACCUMULATOR_BITS, DEFAULT_RESOLUTION_UJ,
                               AmdSmiBackend)


# ------------------------------------------------ fixtures: fake tools

ROCM_ENERGY = {
    "card0": {"Energy counter": "1000000",
              "Accumulated Energy (uJ)": "15259000.0"},
    "card1": {"Energy counter": "2000000",
              "Accumulated Energy (uJ)": "30518000.0"},
}
ROCM_POWER = {
    "card0": {"Average Graphics Package Power (W)": "97.0"},
    "card1": {"Current Socket Graphics Package Power (W)": "105.5"},
}
AMD_ENERGY = [
    {"gpu": 0, "energy": {
        "total_energy_consumption": {"value": 123.5, "unit": "J"},
        "energy_accumulator": 8093946901,
        "counter_resolution": {"value": 15.259, "unit": "uJ"}}},
]
AMD_POWER = [
    {"gpu": 0, "power": {
        "socket_power": {"value": 150.0, "unit": "W"}}},
]


def _rocm_runner(energy=ROCM_ENERGY, power=ROCM_POWER):
    def run(argv, timeout_s):
        if "--showenergycounter" in argv:
            return json.dumps(energy)
        if "--showpower" in argv:
            return json.dumps(power)
        raise BackendError(f"fake rocm-smi: unknown args {argv[1:]}")
    return run


def _amd_runner(energy=AMD_ENERGY, power=AMD_POWER):
    def run(argv, timeout_s):
        if "--energy" in argv:
            return json.dumps(energy)
        if "--power" in argv:
            return json.dumps(power)
        raise BackendError(f"fake amd-smi: unknown args {argv[1:]}")
    return run


def _rapl_tree(tmp_path):
    root = tmp_path / "powercap"
    zones = {
        "intel-rapl:0": ("package-0", "262143328850", "900000"),
        "intel-rapl:0:0": ("core", "262143328850", "400000"),
        "intel-rapl:1": ("package-1", "262143328850", "800000"),
        "psys-0": ("psys", "1000000", "123456"),
    }
    for zone, (name, max_uj, uj) in zones.items():
        d = root / zone
        d.mkdir(parents=True)
        (d / "name").write_text(name + "\n")
        (d / "max_energy_range_uj").write_text(max_uj + "\n")
        (d / "energy_uj").write_text(uj + "\n")
    # a zone with a corrupt declared range must be skipped, not fatal
    bad = root / "intel-rapl:2"
    bad.mkdir()
    (bad / "name").write_text("package-2\n")
    (bad / "max_energy_range_uj").write_text("garbage\n")
    (bad / "energy_uj").write_text("1\n")
    return root


def _hwmon_tree(tmp_path):
    root = tmp_path / "hwmon"
    gpu = root / "hwmon0"
    gpu.mkdir(parents=True)
    (gpu / "name").write_text("amdgpu\n")
    (gpu / "power1_input").write_text("25000000\n")      # 25 W
    cpu = root / "hwmon1"
    cpu.mkdir()
    (cpu / "name").write_text("amd_energy\n")
    (cpu / "energy1_input").write_text("123000000\n")    # 123 J
    return root


def _counter_trace(name, p_w=20.0, span=2.0, dt=0.005, wrap_range=0.0):
    """Constant-power cumulative counter, optionally wrapping at the
    DECLARED ``wrap_range`` joules."""
    t = np.arange(0.0, span + dt / 2, dt)
    v = p_w * t
    if wrap_range:
        v = np.mod(v, wrap_range)
    spec = SensorSpec(name=name, scope="chip", kind="energy_cum",
                      quantum=1e-6, wrap_range_j=wrap_range)
    return SensorTrace(name, spec, t, t.copy(), v)


def _make_backend(kind, tmp_path):
    if kind == "rocm":
        return RocmSmiBackend(tool_path="/fake/rocm-smi",
                              runner=_rocm_runner())
    if kind == "amd":
        return AmdSmiBackend(tool_path="/fake/amd-smi",
                             runner=_amd_runner())
    if kind == "rapl":
        return RaplBackend(root=_rapl_tree(tmp_path))
    if kind == "hwmon":
        return HwmonBackend(root=_hwmon_tree(tmp_path))
    if kind == "sim":
        power = SensorTrace(
            "gpu0.power",
            SensorSpec(name="gpu0.power", scope="chip",
                       kind="power_inst"),
            np.asarray([0.0, 0.1]), np.asarray([0.0, 0.1]),
            np.asarray([50.0, 55.0]))
        return SimBackend({"gpu0.energy": _counter_trace("gpu0.energy",
                                                         wrap_range=64.0),
                           "gpu0.power": power}, speed=1e6)
    raise AssertionError(kind)


# ------------------------------------------------ protocol conformance

@pytest.fixture(params=["rocm", "amd", "rapl", "hwmon", "sim"])
def backend(request, tmp_path):
    return _make_backend(request.param, tmp_path)


def test_backend_conformance(backend):
    """Every adapter honours the SensorBackend protocol: non-empty
    cached discovery, per-metric specs with declared counter semantics,
    SI readings, and BackendError (not crashes) on unknown metrics."""
    specs = backend.discover()
    assert specs, backend.name
    assert backend.available()
    assert backend.discover() == specs          # discovery is cached
    assert backend.rediscover() == specs
    for sp in specs:
        assert isinstance(sp, MetricSpec)
        assert sp.kind in ("energy_cum", "power_inst")
        assert sp.source == backend.name
        assert backend.spec(sp.metric) == sp
        if sp.is_cumulative:
            # the ingest-backend invariant: wrap ranges are DECLARED
            assert sp.wrap_range_j > 0.0, sp.metric
            assert sp.sensor_spec().wrap_period_j \
                == pytest.approx(sp.wrap_range_j)
        r = backend.read(sp.metric)
        assert isinstance(r, Reading)
        assert r.metric == sp.metric
        assert r.source == backend.name
        assert np.isfinite(r.value)
        assert r.t_measured <= r.t_read or r.t_measured == r.t_read
    with pytest.raises(BackendError):
        backend.read("nonexistent.metric")
    with pytest.raises(BackendError):
        backend.spec("nonexistent.metric")
    backend.close()


# ------------------------------------------------ SMI output parsing

def test_rocm_smi_resolution_recovered_from_counter_ratio():
    b = RocmSmiBackend(tool_path="/fake", runner=_rocm_runner())
    sp = b.spec("gpu0.energy")
    # 15259000 uJ over 1e6 ticks -> 15.259 uJ/tick, declared in joules
    assert sp.resolution_j == pytest.approx(15.259e-6)
    assert sp.wrap_range_j == pytest.approx(
        (2.0 ** ACCUMULATOR_BITS) * 15.259e-6)
    r = b.read("gpu0.energy")
    assert r.value == pytest.approx(15.259)       # uJ -> J
    assert b.read("gpu0.power").value == pytest.approx(97.0)
    assert b.read("gpu1.power").value == pytest.approx(105.5)
    assert {sp.metric for sp in b.discover()} == {
        "gpu0.energy", "gpu1.energy", "gpu0.power", "gpu1.power"}


def test_rocm_smi_default_resolution_without_ticks():
    doc = {"card0": {"Accumulated Energy (uJ)": "100.0"}}
    b = RocmSmiBackend(tool_path="/fake", runner=_rocm_runner(doc, {}))
    sp = b.spec("gpu0.energy")
    assert sp.resolution_j == pytest.approx(DEFAULT_RESOLUTION_UJ * 1e-6)


def test_amd_smi_declares_counter_resolution_verbatim():
    b = AmdSmiBackend(tool_path="/fake", runner=_amd_runner())
    sp = b.spec("gpu0.energy")
    assert sp.resolution_j == pytest.approx(15.259e-6)
    assert sp.wrap_range_j == pytest.approx(
        (2.0 ** ACCUMULATOR_BITS) * 15.259e-6)
    assert b.read("gpu0.energy").value == pytest.approx(123.5)
    assert b.read("gpu0.power").value == pytest.approx(150.0)


def test_amd_smi_resolution_from_accumulator_ratio():
    doc = [{"gpu": 0, "energy": {
        "total_energy_consumption": {"value": 100.0, "unit": "J"},
        "energy_accumulator": 50}}]
    b = AmdSmiBackend(tool_path="/fake", runner=_amd_runner(doc, []))
    assert b.spec("gpu0.energy").resolution_j == pytest.approx(2.0)


def test_smi_accumulator_wrap_unwraps_with_declared_period():
    """A 64-bit accumulator wrap unwraps exactly with the DECLARED
    period — the downstream unwrap never has to guess the range."""
    b = RocmSmiBackend(tool_path="/fake", runner=_rocm_runner())
    period = b.spec("gpu0.energy").sensor_spec().wrap_period_j
    vals = np.asarray([period - 1.0, 1.0])        # wrapped across zero
    un = unwrap_counter(vals, period=period)
    assert un[1] - un[0] == pytest.approx(2.0)


def test_smi_disabled_via_env(monkeypatch):
    monkeypatch.setenv("REPRO_INGEST_DISABLE", "rocm-smi")
    b = RocmSmiBackend(tool_path="/fake", runner=_rocm_runner())
    assert not b.available()


# ------------------------------------------------ RAPL / hwmon sysfs

def test_rapl_zone_naming_and_declared_wrap(tmp_path):
    b = RaplBackend(root=_rapl_tree(tmp_path))
    metrics = {sp.metric: sp for sp in b.discover()}
    assert set(metrics) == {"cpu0.energy", "cpu0.core.energy",
                            "cpu1.energy", "psys.energy"}
    sp = metrics["cpu0.energy"]
    assert sp.wrap_range_j == pytest.approx(262143.32885)
    assert sp.resolution_j == pytest.approx(1e-6)
    assert b.read("cpu0.energy").value == pytest.approx(0.9)
    # corrupt package-2 zone was skipped, not fatal
    assert "cpu2.energy" not in metrics


def test_rapl_wraps_at_declared_max_energy_range(tmp_path):
    root = _rapl_tree(tmp_path)
    b = RaplBackend(root=root)
    sp = b.spec("psys.energy")
    assert sp.wrap_range_j == pytest.approx(1.0)  # 1e6 uJ
    v0 = b.read("psys.energy").value
    (root / "psys-0" / "energy_uj").write_text("900000\n")
    v1 = b.read("psys.energy").value
    (root / "psys-0" / "energy_uj").write_text("100000\n")  # wrapped
    v2 = b.read("psys.energy").value
    un = unwrap_counter(np.asarray([v0, v1, v2]),
                        period=sp.sensor_spec().wrap_period_j)
    assert un[2] - un[1] == pytest.approx(0.2)    # +200 mJ, not -800
    assert np.all(np.diff(un) > 0)


def test_hwmon_channels_scales_and_gpu_mapping(tmp_path):
    b = HwmonBackend(root=_hwmon_tree(tmp_path))
    metrics = {sp.metric: sp for sp in b.discover()}
    assert set(metrics) == {"gpu0.power", "amd_energy1.energy"}
    assert metrics["gpu0.power"].kind == "power_inst"
    assert b.read("gpu0.power").value == pytest.approx(25.0)
    sp = metrics["amd_energy1.energy"]
    assert sp.wrap_range_j == pytest.approx((2.0 ** 64) * 1e-6)
    assert b.read("amd_energy1.energy").value == pytest.approx(123.0)


def test_backends_unavailable_on_missing_roots(tmp_path):
    assert not RaplBackend(root=tmp_path / "nope").available()
    assert not HwmonBackend(root=tmp_path / "nope").available()


# ------------------------------------------------ prioritized ingest

class _Clock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def tick(self, dt):
        self.t += dt
        return self.t


class _FakeBackend(SensorBackend):
    """Scriptable backend: togglable failure, counting reads."""

    def __init__(self, name, metrics=("m",), clock=None, fail=False):
        super().__init__(clock=clock or _Clock())
        self.name = name
        self._metrics = list(metrics)
        self.fail = fail
        self.reads = 0
        self._v = 0.0

    def _discover(self):
        return [MetricSpec(m, "energy_cum", wrap_range_j=1e3,
                           resolution_j=1e-6, source=self.name)
                for m in self._metrics]

    def read(self, metric):
        self.reads += 1
        if self.fail:
            raise BackendError(f"{self.name} is down")
        if metric not in self._metrics:
            raise BackendError(f"unknown {metric!r}")
        self._v += 1.0
        t = self._clock()
        return Reading(metric, t, t, self._v, self.name)


def test_priority_fallback_to_next_provider():
    clk = _Clock()
    a = _FakeBackend("a", clock=clk, fail=True)
    b = _FakeBackend("b", clock=clk)
    ing = PrioritizedIngest([a, b], clock=clk)
    r = ing.read("m")
    assert r.source == "b" and not r.cached
    assert ing.counters["a"]["errors"] == 1
    assert ing.counters["b"]["fallbacks"] == 1
    assert ing.counters["b"]["reads"] == 1


def test_priority_demotion_retry_and_recovery():
    clk = _Clock()
    a = _FakeBackend("a", clock=clk, fail=True)
    b = _FakeBackend("b", clock=clk)
    pol = IngestPolicy(error_budget=2, retry_after_s=5.0)
    ing = PrioritizedIngest([a, b], policy=pol, clock=clk)
    ing.read("m")
    ing.read("m")                       # second failure -> demotion
    assert ing.counters["a"]["demotions"] == 1
    assert a.reads == 2
    ing.read("m")                       # demoted: a is not even tried
    assert a.reads == 2
    [ev] = [e for e in ing.events if e.state_to == QUARANTINED]
    assert ev.kind == "ingest" and ev.name == "a:m"
    clk.tick(6.0)                       # past retry_after_s
    a.fail = False
    r = ing.read("m")
    assert r.source == "a" and a.reads == 3
    assert ing.counters["a"]["recoveries"] == 1
    [rev] = [e for e in ing.events if e.state_to == HEALTHY]
    assert rev.name == "a:m" and "recovered" in rev.flags


def test_cache_serves_last_good_until_stale():
    clk = _Clock()
    a = _FakeBackend("a", clock=clk)
    pol = IngestPolicy(stale_ttl_s=0.25, error_budget=99)
    ing = PrioritizedIngest([a], policy=pol, clock=clk)
    good = ing.read("m")
    a.fail = True
    clk.tick(0.1)                       # inside the TTL: cached serve
    r = ing.read("m")
    assert r.cached and r.value == good.value
    assert ing.counters["a"]["cache_hits"] == 1
    clk.tick(1.0)                       # cache now stale
    with pytest.raises(IngestUnavailable):
        ing.read("m")


def test_per_metric_priority_override_and_spec():
    clk = _Clock()
    a = _FakeBackend("a", clock=clk)
    b = _FakeBackend("b", clock=clk)
    ing = PrioritizedIngest([a, b], priority={"m": ["b", "a"]},
                            clock=clk)
    assert [bk.name for bk in ing.providers("m")] == ["b", "a"]
    assert ing.spec("m").source == "b"
    r = ing.read("m")
    assert r.source == "b"
    assert ing.counters["b"]["fallbacks"] == 0    # b is rank 0 here
    with pytest.raises(IngestUnavailable):
        ing.spec("nope")


def test_ingest_counters_export_through_registry():
    clk = _Clock()
    reg = HealthRegistry()
    ing = PrioritizedIngest([_FakeBackend("a", clock=clk)],
                            clock=clk, registry=reg)
    ing.read("m")
    text = reg.prometheus_text()
    assert "ingest_reads_total" in text
    assert 'backend="a"' in text


def test_events_sink_receives_transitions():
    clk = _Clock()
    sink = []
    a = _FakeBackend("a", clock=clk, fail=True)
    b = _FakeBackend("b", clock=clk)
    ing = PrioritizedIngest([a, b], clock=clk, events=sink,
                            policy=IngestPolicy(error_budget=1))
    ing.read("m")
    assert len(sink) == 1 and sink[0].state_to == QUARANTINED


def test_default_backend_order_env(monkeypatch):
    monkeypatch.delenv("REPRO_INGEST_PRIORITY", raising=False)
    assert default_backend_order() == ["rocm-smi", "amd-smi", "rapl",
                                       "hwmon", "sim"]
    monkeypatch.setenv("REPRO_INGEST_PRIORITY", "rapl , sim")
    assert default_backend_order() == ["rapl", "sim"]


# ------------------------------------------------ reader + async pump

def test_backend_reader_dedupes_stale_publications():
    clk = _Clock()
    a = _FakeBackend("a", clock=clk)
    ing = PrioritizedIngest([a], clock=clk)
    rd = BackendReader(ing, "m")
    t, v = rd.poll(clk())
    assert len(t) == 1
    # frozen clock -> same t_measured -> deduped at the boundary
    t, v = rd.poll(clk())
    assert len(t) == 0 and rd.n_dupes == 1
    clk.tick(0.5)
    t, v = rd.poll(clk())
    assert len(t) == 1
    a.fail = True
    clk.tick(10.0)                      # cache stale too
    t, v = rd.poll(clk())
    assert len(t) == 0 and rd.n_unavailable == 1
    assert not rd.drained
    rd.stop()
    assert rd.drained


def test_backend_reader_t_stop_bound():
    clk = _Clock()
    a = _FakeBackend("a", clock=clk)
    ing = PrioritizedIngest([a], clock=clk)
    rd = BackendReader(ing, "m", t_stop=clk.t)
    rd.poll(clk())                      # t_measured == t_stop
    assert rd.drained


class _ListReader:
    """Replays scripted (t, v) poll batches."""

    def __init__(self, batches):
        self._batches = [(np.asarray(t, np.float64),
                          np.asarray(v, np.float64))
                         for t, v in batches]

    def poll(self, now_wall):
        if self._batches:
            return self._batches.pop(0)
        return np.empty((0,)), np.empty((0,))

    @property
    def drained(self):
        return not self._batches


class _CapStream:
    def __init__(self):
        self.calls = []

    def update(self, t, e):
        self.calls.append((np.array(t), np.array(e)))


def test_async_ingest_dedupes_duplicate_timestamps():
    """Coarse sensor clocks re-deliver publications; only strictly
    advancing timestamps reach the stream, reorders pass through."""
    rd = _ListReader([
        ([1.0, 1.0, 2.0, 2.0, 3.0], [10.0, 10.0, 20.0, 20.0, 30.0]),
        ([3.0, 4.0], [30.0, 40.0]),     # cross-poll re-delivery
        ([5.0, 4.5], [50.0, 45.0]),     # genuine reorder: kept
    ])
    cap = _CapStream()
    pump = AsyncFleetIngest([rd], cap, t0=0.0, chunk=8)
    for _ in range(3):
        pump._poll_once()
    assert pump.n_dupes == 3            # two in-batch + one cross-poll
    assert pump._buf[0][0] == [1.0, 2.0, 3.0, 4.0, 5.0, 4.5]
    pump._flush()
    (t_blk, e_blk), = cap.calls
    # replicate-last padding up to the chunk width
    np.testing.assert_allclose(
        t_blk[0], [1.0, 2.0, 3.0, 4.0, 5.0, 4.5, 4.5, 4.5])
    np.testing.assert_allclose(e_blk[0][-3:], [45.0, 45.0, 45.0])
    assert pump.bounds[0] == (1.0, 10.0, 4.5, 45.0)


def test_async_ingest_jitter_dephases_poll_clock():
    with pytest.raises(AssertionError):
        AsyncFleetIngest([_ListReader([])], _CapStream(), t0=0.0,
                         jitter=1.5)
    rng = np.random.default_rng(0)
    waits = 1e-3 * (1.0 + 0.25 * rng.uniform(-1.0, 1.0, 100))
    assert np.std(waits) > 0.0          # the de-phasing is real
    assert np.all(waits > 0.0)


def test_async_ingest_requires_readers():
    with pytest.raises(AssertionError):
        AsyncFleetIngest([], _CapStream(), t0=0.0)


def test_backend_reader_forwards_reordered_timestamps():
    """Only duplicate publications are deduped at the reader boundary;
    strictly-decreasing timestamps (genuine reorders) pass through to
    the pipeline's dq accounting."""
    clk = _Clock()
    a = _FakeBackend("a", clock=clk)
    ing = PrioritizedIngest([a], clock=clk)
    rd = BackendReader(ing, "m")
    t, _ = rd.poll(clk())
    assert len(t) == 1
    clk.tick(-0.2)                      # tool clock stepped backwards
    t, _ = rd.poll(clk())
    assert len(t) == 1 and rd.n_dupes == 0    # reorder: forwarded
    t, _ = rd.poll(clk())               # same stale stamp re-published
    assert len(t) == 0 and rd.n_dupes == 1    # duplicate: deduped
    clk.tick(0.5)
    t, _ = rd.poll(clk())
    assert len(t) == 1


def test_async_ingest_dead_row_zero_energy_and_safe_drain():
    """A reader that never produces one sample (every provider failing
    from the start) must not stall the live rows' flushes or crash the
    stop() drain — and must cost exactly zero energy, not the capture."""
    from repro.fleet import FleetStream
    tt = np.linspace(0.0, 2.0, 9)
    vv = 10.0 * tt
    live = _ListReader([(tt[:5], vv[:5]), (tt[5:], vv[5:])])
    dead = _ListReader([])
    stream = FleetStream([(0.0, 3.0)], 2, wrap_period=[0.0, 0.0])
    pump = AsyncFleetIngest([live, dead], stream, t0=0.0, chunk=4)
    pump._poll_once()
    # the dead row no longer blocks the periodic flush condition
    assert max(len(b[0]) for b in pump._buf) >= pump._chunk
    pump._flush()                       # dead row: masked placeholders
    pump.stop()                         # drain must not raise
    assert pump.n_chunks >= 2
    totals = np.asarray(stream.totals(), np.float64)
    assert totals[0].sum() == pytest.approx(float(vv[-1] - vv[0]))
    assert totals[1].sum() == 0.0


def test_async_ingest_late_row_seeds_without_fabricated_delta():
    """A row dark through the first flush seeds at its FIRST real
    sample when it comes alive: the jump from the masked placeholder
    to a large counter value carries no fabricated energy."""
    from repro.fleet import FleetStream
    tt = np.linspace(0.0, 2.0, 9)
    vv = 10.0 * tt
    live = _ListReader([(tt[:5], vv[:5]), (tt[5:], vv[5:])])
    late = _ListReader([(np.empty((0,)), np.empty((0,))),
                        ([1.0, 1.5, 2.0], [500.0, 505.0, 510.0])])
    stream = FleetStream([(0.0, 3.0)], 2, wrap_period=[0.0, 0.0])
    pump = AsyncFleetIngest([live, late], stream, t0=0.0, chunk=4)
    pump._poll_once()                   # late row still dark
    pump._flush()                       # -> masked placeholders
    pump.stop()                         # late row arrives in the drain
    totals = np.asarray(stream.totals(), np.float64)
    assert totals[0].sum() == pytest.approx(float(vv[-1] - vv[0]))
    # seeded zero-width at 500 J: only the 10 J actually accumulated
    assert totals[1].sum() == pytest.approx(10.0)


def test_rocm_smi_non_contiguous_cards_map_to_discovery():
    """rocm-smi may report non-contiguous card keys; reads must target
    the card each metric was DISCOVERED from, with one card->gpu index
    shared by the energy and power documents."""
    energy = {"card0": {"Energy counter": "1000000",
                        "Accumulated Energy (uJ)": "15259000.0"},
              "card2": {"Energy counter": "2000000",
                        "Accumulated Energy (uJ)": "30518000.0"}}
    power = {"card2": {"Average Graphics Package Power (W)": "42.0"}}
    b = RocmSmiBackend(tool_path="/fake",
                       runner=_rocm_runner(energy, power))
    assert {sp.metric for sp in b.discover()} == {
        "gpu0.energy", "gpu1.energy", "gpu1.power"}
    # gpu1.* was declared from card2 -> reads card2, not card1
    assert b.read("gpu1.energy").value == pytest.approx(30.518)
    assert b.read("gpu1.power").value == pytest.approx(42.0)
    with pytest.raises(BackendError):
        b.read("gpu0.power")            # card0 declared no power


def test_simulated_smi_reader_shutdown_conservation():
    """Satellite regression: the promoted SimulatedSMIReader +
    AsyncFleetIngest pump conserves counter energy through stop() —
    stream totals equal the unwrapped first->last counter delta."""
    from repro.fleet import FleetStream
    truth = square_wave(1.0, 2, lead_s=0.5, tail_s=0.5)
    spec = SensorSpec(name="e0", scope="chip", kind="energy_cum",
                      quantum=1e-6, wrap_bits=26)
    tool = ToolSpec(0.9e-3)
    tr = simulate_sensor(spec, tool, truth, seed=0)
    reader = SimulatedSMIReader(tr, speed=64.0)
    t0 = float(tr.t_measured[0])
    span = float(tr.t_measured[-1]) - t0
    stream = FleetStream([(0.0, span + 1.0)], 1,
                         wrap_period=[tr.spec.wrap_period_j])
    pump = AsyncFleetIngest([reader], stream, t0, chunk=64,
                            interval_s=1e-3).start()
    deadline = time.perf_counter() + 30.0
    while not reader.drained and time.perf_counter() < deadline:
        time.sleep(1e-3)
    pump.stop()
    assert reader.drained
    assert pump.n_chunks >= 2
    assert pump.n_dupes > 0             # the busy-poll re-delivery bug
    # expected counter delta over the whole replay (the boundary pair
    # alone cannot see multiple wraps; the full series can)
    un = unwrap_counter(tr.value, period=tr.spec.wrap_period_j)
    expect = float(un[-1] - un[0])
    tf, ef, tl, el = pump.bounds[0]
    assert ef == pytest.approx(float(tr.value[0]))
    got = float(np.asarray(stream.totals())[0].sum())
    assert abs(got - expect) <= max(1e-3 * abs(expect), 1e-3), \
        (got, expect)


# ------------------------------------------------ live e2e: mid-run kill

class _Killable(SensorBackend):
    """Proxy over a SimBackend that dies after ``n_ok`` reads."""

    name = "sim-primary"

    def __init__(self, inner, n_ok):
        super().__init__(clock=inner._clock)
        self._inner = inner
        self._n_ok = n_ok
        self.reads = 0

    def _discover(self):
        return [dataclasses.replace(sp, source=self.name)
                for sp in self._inner.discover()]

    def read(self, metric):
        self.reads += 1
        if self.reads > self._n_ok:
            raise BackendError("killed mid-run")
        return dataclasses.replace(self._inner.read(metric),
                                   source=self.name)


class _ChainedSim(SimBackend):
    """SimBackend sharing a leader's replay origin, so a fallback
    read continues exactly where the dead backend stopped."""

    name = "sim-backup"

    def __init__(self, traces, leader, **kw):
        super().__init__(traces, **kw)
        self._leader = leader

    def _t_sim(self):
        if self._leader._t0_wall is not None:
            self._t0_wall = self._leader._t0_wall
        return super()._t_sim()


def test_live_backend_kill_falls_back_without_dropping_windows():
    """Acceptance: killing the preferred backend mid-run falls down
    the priority list without an unavailable poll or a lost window —
    phase energies still match the constant-power ground truth."""
    p_w, span = 20.0, 2.0
    tr = _counter_trace("gpu0.energy", p_w=p_w, span=span, dt=0.005,
                        wrap_range=15.0)       # wraps ~2x mid-capture
    inner = SimBackend({"gpu0.energy": tr}, speed=8.0)
    primary = _Killable(inner, n_ok=25)
    backup = _ChainedSim({"gpu0.energy": tr}, leader=inner, speed=8.0)
    ingest = PrioritizedIngest(
        [primary, backup],
        policy=IngestPolicy(error_budget=1, retry_after_s=60.0,
                            stale_ttl_s=0.05))
    res = attribute_live([("first", 0.0, 1.0), ("second", 1.0, 2.0)],
                         duration_s=0.6, ingest=ingest,
                         metrics=["gpu0.energy"], chunk=16,
                         interval_s=2e-3, window=128, hop=64,
                         max_lag=8, tail=64)
    # the kill happened, was demoted once, and the backup took over
    assert primary.reads > 25
    assert ingest.counters["sim-primary"]["demotions"] == 1
    assert ingest.counters["sim-backup"]["fallbacks"] > 0
    assert any(e.state_to == QUARANTINED for e in ingest.events)
    # no dropped windows: every poll produced data or a clean dedupe
    assert sum(r.n_unavailable for r in res.readers) == 0
    assert res.pump.n_chunks >= 3
    e = res.energies()
    assert abs(e["first"]["gpu0"] - p_w * 1.0) <= 1.0, e
    assert abs(e["second"]["gpu0"] - p_w * 1.0) <= 1.0, e
