"""Per-kernel allclose validation against the pure-jnp oracles, sweeping
shapes and dtypes (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.phase_integrate.ops import phase_energies
from repro.kernels.phase_integrate.ref import phase_energies_ref
from repro.kernels.power_reconstruct.ops import reconstruct_power
from repro.kernels.power_reconstruct.ref import reconstruct_power_ref
from repro.kernels.squarewave.ops import (calibrated_fma_count,
                                          squarewave_load)
from repro.kernels.squarewave.ref import squarewave_ref
from repro.kernels.ssm_scan.ops import selective_scan
from repro.kernels.ssm_scan.ref import selective_scan_ref


# ---------------------------------------------------------------- squarewave
@pytest.mark.parametrize("shape", [(256, 128), (512, 256), (1024, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_squarewave(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    out = squarewave_load(x, fma_chain=17, interpret=True)
    ref = squarewave_ref(x, fma_chain=17)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=rtol)


def test_calibrated_fma_count_matches_balance():
    k32 = calibrated_fma_count(jnp.float32)
    k16 = calibrated_fma_count(jnp.bfloat16)
    # flops/byte = 2K/(2*itemsize) must equal the machine balance
    assert abs(2 * k32 / 8.0 - 197e12 / 819e9 * 1.0) < 1.0
    assert abs(k32 - 2 * k16) <= 2


# ---------------------------------------------------------- power_reconstruct
@pytest.mark.parametrize("n,s", [(8, 512), (16, 1024), (4, 4096)])
@pytest.mark.parametrize("wrap", [0.0, 50.0])
def test_power_reconstruct(n, s, wrap):
    rng = np.random.default_rng(int(n + s))
    t = np.cumsum(rng.uniform(0.5e-3, 1.5e-3, (n, s)), axis=1)
    t = t.astype(np.float32)
    p = rng.uniform(50, 250, (n, s)).astype(np.float32)
    dt = np.diff(t, axis=1, prepend=t[:, :1] - 1e-3)
    e = np.cumsum(p * dt, axis=1)
    if wrap:
        e = np.mod(e, wrap)
    out = reconstruct_power(jnp.array(e), jnp.array(t), wrap_period=wrap,
                            interpret=True)
    ref = reconstruct_power_ref(jnp.array(e), jnp.array(t),
                                wrap_period=wrap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-2)
    # reconstruction ~ recovers the true power away from wrap edges
    if not wrap:
        np.testing.assert_allclose(np.asarray(out)[:, 2:], p[:, 2:],
                                   rtol=0.35)


# ---------------------------------------------- power_reconstruct (per-row)
@pytest.mark.parametrize("n,s", [(8, 512), (16, 1024)])
def test_power_reconstruct_rows(n, s):
    """Heterogeneous wrap periods: per-row kernel vs per-row oracle, and
    vs the scalar-wrap kernel on homogeneous rows."""
    from repro.kernels.power_reconstruct.kernel import \
        power_reconstruct_rows_kernel
    from repro.kernels.power_reconstruct.ref import \
        reconstruct_power_rows_ref
    rng = np.random.default_rng(int(n + s))
    t = np.cumsum(rng.uniform(0.5e-3, 1.5e-3, (n, s)), axis=1)
    t = t.astype(np.float32)
    p = rng.uniform(50, 250, (n, s)).astype(np.float32)
    dt = np.diff(t, axis=1, prepend=t[:, :1] - 1e-3)
    e = np.cumsum(p * dt, axis=1)
    wrap = np.where(np.arange(n) % 2 == 0, 50.0, 0.0).astype(np.float32)
    e = np.where(wrap[:, None] > 0, np.mod(e, 50.0), e).astype(np.float32)
    out = power_reconstruct_rows_kernel(jnp.array(e), jnp.array(t),
                                        jnp.array(wrap)[:, None],
                                        interpret=True)
    ref = reconstruct_power_rows_ref(jnp.array(e), jnp.array(t),
                                     jnp.array(wrap)[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-2)
    # homogeneous no-wrap rows agree with the legacy scalar-wrap kernel
    legacy = reconstruct_power(jnp.array(e[1::2]), jnp.array(t[1::2]),
                               wrap_period=0.0, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[1::2], np.asarray(legacy),
                               rtol=1e-4, atol=1e-2)


# ------------------------------------------------------------ fleet_attribute
@pytest.mark.parametrize("n,s,p", [(8, 512, 8), (16, 300, 32)])
def test_fleet_attribute_fused(n, s, p):
    """Fused ΔE/Δt+integrate kernel == composition of the stage oracles."""
    from repro.kernels.fleet_attribute.kernel import fleet_attribute_kernel
    from repro.kernels.fleet_attribute.ref import fleet_attribute_ref
    rng = np.random.default_rng(int(n * s + p))
    t = np.cumsum(rng.uniform(0.5e-3, 1.5e-3, (n, s)),
                  axis=1).astype(np.float32)
    pw = rng.uniform(50, 250, (n, s)).astype(np.float32)
    dt = np.diff(t, axis=1, prepend=t[:, :1] - 1e-3)
    e = np.cumsum(pw * dt, axis=1).astype(np.float32)
    wrap = np.zeros((n, 1), np.float32)
    ph = np.sort(rng.uniform(t.min(), t.max(), (p, 2)).astype(np.float32),
                 axis=1)
    out = fleet_attribute_kernel(jnp.array(t), jnp.array(e),
                                 jnp.array(wrap), jnp.array(ph),
                                 interpret=True)
    ref = fleet_attribute_ref(jnp.array(t), jnp.array(e), jnp.array(wrap),
                              jnp.array(ph))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------ phase_integrate
@pytest.mark.parametrize("n,s,p", [(8, 256, 32), (16, 1000, 64)])
def test_phase_integrate(n, s, p):
    rng = np.random.default_rng(int(n * s + p))
    t = np.cumsum(rng.uniform(0.5e-3, 1.5e-3, (n, s)), axis=1)
    t = t.astype(np.float32)
    w = rng.uniform(50, 250, (n, s)).astype(np.float32)
    ph = np.sort(rng.uniform(t.min(), t.max(), (p, 2)).astype(np.float32),
                 axis=1)
    out = phase_energies(jnp.array(t), jnp.array(w), jnp.array(ph),
                         interpret=True)
    ref = phase_energies_ref(jnp.array(t), jnp.array(w), jnp.array(ph))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 256, 64), (1, 8, 8, 128, 64), (2, 8, 2, 256, 128),
])
@pytest.mark.parametrize("cap", [0.0, 50.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, hq, hkv, s, d, cap, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal=True, logit_cap=cap,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, logit_cap=cap)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------------------------------------------ ssm_scan
@pytest.mark.parametrize("b,l,d,n", [(2, 64, 256, 16), (1, 128, 128, 8)])
def test_ssm_scan(b, l, d, n):
    ks = jax.random.split(jax.random.key(1), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, l, d))) * 0.1
    x = jax.random.normal(ks[1], (b, l, d))
    bm = jax.random.normal(ks[2], (b, l, n))
    cm = jax.random.normal(ks[3], (b, l, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.1)
    h0 = jax.random.normal(ks[5], (b, d, n)) * 0.1
    y, h = selective_scan(dt, x, bm, cm, a, h0, interpret=True)
    yr, hr = selective_scan_ref(dt, x, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=3e-4, atol=3e-4)


def test_ssm_kernel_matches_model_layer():
    """The Pallas kernel implements the same recurrence as the model's
    chunked associative scan (drop-in replacement check)."""
    from repro.models.mamba import _chunk_scan
    b, s, d, n = 2, 64, 128, 16
    ks = jax.random.split(jax.random.key(2), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d))) * 0.1
    x = jax.random.normal(ks[1], (b, s, d))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.1)
    h0 = jnp.zeros((b, d, n))
    y_k, h_k = selective_scan(dt, x, bm, cm, a, h0, interpret=True)
    y_m, h_m = _chunk_scan(dt, bm, cm, a, x, h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=3e-4, atol=3e-4)
