"""Prefill/decode consistency + trace-format roundtrip + serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma2-27b",
                                  "jamba-1.5-large-398b", "xlstm-1.3b",
                                  "whisper-base"])
def test_prefill_vs_stepwise_decode(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    n = 10
    toks = jax.random.randint(jax.random.key(2), (1, n), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "audio":
        extra["audio_frames"] = jnp.ones(
            (1, cfg.num_audio_frames, cfg.d_model), jnp.bfloat16)
    cache = model.init_cache(1, 32)
    lg_pre, _ = jax.jit(model.prefill)(
        params, {"tokens": toks, **extra}, cache)
    cache = model.init_cache(1, 32)
    step = jax.jit(model.decode_step)
    if cfg.family == "audio":
        # decode needs cross-kv: prefill the first token to fill it
        lg, cache = jax.jit(model.prefill)(
            params, {"tokens": toks[:, :1], **extra}, cache)
        start = 1
    else:
        start = 0
        lg = None
    for i in range(start, n):
        lg, cache = step(params, {"tokens": toks[:, i:i + 1]}, cache,
                         jnp.asarray(i, jnp.int32))
    a = np.asarray(lg_pre[0, -1], np.float32)
    b = np.asarray(lg[0, 0], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-2, rtol=1e-2)


def test_trace_format_roundtrip(tmp_path):
    from repro.core import (NodeFabric, RegionTracer, ToolSpec, load_trace,
                            merge_traces, save_trace, square_wave)
    truth = square_wave(1.0, 2, lead_s=0.5, tail_s=0.5)
    fabric = NodeFabric(chip_truths=[truth] * 4)
    traces = fabric.sample_all(ToolSpec(1e-2), seed=0)
    tracer = RegionTracer(timebase=lambda: 0.0)
    tracer.add_region("warmup", 0.0, 0.5)
    tracer.add_region("work", 0.5, 2.0, step=1)
    p1 = tmp_path / "node0.npz"
    save_trace(p1, tracer, traces, meta={"node_id": 0})
    t2, s2, meta = load_trace(p1)
    assert meta["node_id"] == 0
    assert [e.name for e in t2.events] == ["warmup", "work"]
    assert set(s2) == set(traces)
    np.testing.assert_array_equal(s2["chip0_energy"].value,
                                  traces["chip0_energy"].value)
    # merge two nodes
    p2 = tmp_path / "node1.npz"
    save_trace(p2, tracer, traces, meta={"node_id": 1})
    reg, sensors, metas = merge_traces([p1, p2])
    assert len(reg.events) == 4
    assert "node0/chip0_energy" in sensors
    assert "node1/chip0_energy" in sensors


def test_serve_engine_matches_manual_decode():
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced(get_arch("llama3.2-3b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    engine = ServeEngine(model, params, batch_slots=2, max_len=32)
    out = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])
    # manual greedy decode
    cache = model.init_cache(2, 32)
    toks = jnp.asarray(np.stack([prompt, prompt]))
    lg, cache = jax.jit(model.prefill)(params, {"tokens": toks}, cache)
    nxt = int(jnp.argmax(lg[0, -1]))
    manual = [nxt]
    cur = jnp.full((2, 1), nxt, jnp.int32)
    pos = len(prompt)
    step = jax.jit(model.decode_step)
    for _ in range(5):
        lg, cache = step(params, {"tokens": cur}, cache,
                         jnp.asarray(pos, jnp.int32))
        nxt = int(jnp.argmax(lg[0, 0]))
        manual.append(nxt)
        cur = jnp.full((2, 1), nxt, jnp.int32)
        pos += 1
    assert out[0] == manual


def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = d1.batch(5)
    b2 = d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], d1.batch(6)["tokens"])
    # shards are deterministic and labels shift tokens by one
    s0 = d1.batch(5, shard=0, n_shards=2)
    np.testing.assert_array_equal(s0["labels"][:, :-1],
                                  s0["tokens"][:, 1:])


def test_compression_error_feedback_unbiased():
    from repro.distributed.compression import ef_roundtrip
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(0, 1e-3, (256,)), jnp.float32)}
    res = None
    acc = np.zeros(256)
    n = 50
    for _ in range(n):
        rt, res = ef_roundtrip(g_true, res, scheme="bf16")
        acc += np.asarray(rt["w"], np.float32)
    # accumulated compressed grads converge to accumulated true grads
    err = np.abs(acc / n - np.asarray(g_true["w"]))
    assert err.max() < 2e-6


def test_int8_compression_bounds():
    from repro.distributed.compression import int8_compress, int8_decompress
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1.0, (1000,)), jnp.float32)
    q, s, shape, pad = int8_compress(x)
    y = int8_decompress(q, s, shape, pad)
    assert np.max(np.abs(np.asarray(x - y))) <= float(np.max(s)) * 0.51
