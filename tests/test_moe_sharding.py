"""MoE dispatch numerics + sharding-plan rules + HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.moe import _capacity, _moe_local, moe_specs
from repro.models.layers import init_params


def _cfg(e=8, k=2, dff=32):
    return ArchConfig(
        name="t", family="moe", num_layers=2, d_model=16, num_heads=4,
        num_kv_heads=2, d_ff=dff, vocab_size=64, head_dim=8,
        moe=MoEConfig(num_experts=e, top_k=k, expert_d_ff=dff,
                      capacity_factor=8.0))  # big capacity: dropless


def _dense_reference(p, x, moe):
    """Dense all-experts reference: y = Σ_k gate_k * FFN_{e_k}(x)."""
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, moe.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    outs = []
    for e in range(moe.num_experts):
        g = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        outs.append(g @ p["w_down"][e])
    outs = jnp.stack(outs, 1)                 # (N, E, d)
    onehot = jax.nn.one_hot(idx, moe.num_experts)   # (N, k, E)
    w = jnp.einsum("nk,nke->ne", gate, onehot)
    return jnp.einsum("ne,ned->nd", w, outs)


def test_moe_local_matches_dense_reference():
    cfg = _cfg()
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, 16), jnp.float32)
    y, aux = _moe_local(p, x, moe=cfg.moe, expert_offset=0,
                        e_local=cfg.moe.num_experts,
                        capacity=_capacity(64, cfg.moe))
    ref = _dense_reference(p, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_expert_partition_sums_to_whole():
    """Union of per-shard partial outputs == single-shard output (the psum
    correctness property of the EP design)."""
    cfg = _cfg(e=8, k=2)
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (32, 16), jnp.float32)
    full, _ = _moe_local(p, x, moe=cfg.moe, expert_offset=0, e_local=8,
                         capacity=_capacity(32, cfg.moe))
    partial_sum = jnp.zeros_like(full)
    for shard in range(4):
        pl = jax.tree.map(lambda w: w, p)
        pl = dict(p)
        for nm in ("w_gate", "w_up", "w_down"):
            pl[nm] = p[nm][shard * 2:(shard + 1) * 2]
        y, _ = _moe_local(pl, x, moe=cfg.moe, expert_offset=shard * 2,
                          e_local=2, capacity=_capacity(32, cfg.moe))
        partial_sum = partial_sum + y
    np.testing.assert_allclose(np.asarray(partial_sum), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_are_bounded():
    """With capacity_factor=1.0 and adversarial routing, dropped tokens
    lose their contribution but output stays finite."""
    cfg = ArchConfig(
        name="t", family="moe", num_layers=2, d_model=16, num_heads=4,
        num_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8,
        moe=MoEConfig(num_experts=4, top_k=1, expert_d_ff=32,
                      capacity_factor=0.5))
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jnp.broadcast_to(jax.random.normal(jax.random.key(1), (1, 16)),
                         (64, 16))           # all tokens route identically
    y, _ = _moe_local(p, x, moe=cfg.moe, expert_offset=0, e_local=4,
                      capacity=_capacity(64, cfg.moe))
    assert bool(jnp.isfinite(y).all())
    # some rows must be zero (dropped)
    norms = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.min(norms)) == 0.0
    assert float(jnp.max(norms)) > 0.0


def test_sharding_plan_rules():
    import numpy as np
    from jax.sharding import Mesh
    from repro.distributed.sharding import ShardingPlan
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    plan = ShardingPlan(mesh=mesh, fsdp=True, dp_axes=("data",))
    # vocab-bearing tables never FSDP
    spec = plan.spec_for(("vocab", "embed"), (512, 64))
    assert spec == jax.sharding.PartitionSpec("model", None)
    # 2D weight: fsdp x tp
    spec = plan.spec_for(("embed", "mlp"), (64, 128))
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # non-divisible dims stay replicated
    spec = plan.spec_for(("vocab", "embed"), (51865, 64))
    # vocab 51865 % 1 == 0 on this tiny mesh; force a fake big mesh check
    plan2 = ShardingPlan(mesh=mesh, fsdp=False, dp_axes=("data",))
    spec = plan2.spec_for(("embed", "mlp"), (64, 128))
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_hlo_costs_scan_multiplication():
    from repro.launch.hlo_costs import analyze
    from jax import lax

    def scanned(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    a = analyze(jax.jit(scanned).lower(x, ws).compile().as_text())
    assert abs(a["flops"] - 7 * 2 * 256 ** 3) / (7 * 2 * 256 ** 3) < 0.01
