"""Multi-device shard_map correctness: run subprocesses with 8 host devices
(XLA_FLAGS must be set before jax import, hence subprocess isolation)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code, n_devices=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_decode_attention_sharded_matches_oracle():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.decode_attention import decode_attention
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        B, S, HQ, HKV, D = 4, 64, 8, 4, 32
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, 1, HQ, D), jnp.float32)
        ck = jax.random.normal(ks[1], (B, S, HKV, D), jnp.float32)
        cv = jax.random.normal(ks[2], (B, S, HKV, D), jnp.float32)
        pos = jnp.asarray(40, jnp.int32)
        with mesh:
            out = jax.jit(lambda q, k, v: decode_attention(
                q, k, v, pos, mesh))(q, ck, cv)
        ref = decode_attention(q, ck, cv, pos, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        # window + softcap variants
        with mesh:
            out = jax.jit(lambda q, k, v: decode_attention(
                q, k, v, pos, mesh, window=16, logit_cap=30.0))(q, ck, cv)
        ref = decode_attention(q, ck, cv, pos, None, window=16,
                               logit_cap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("decode_attention sharded OK")
    """)


def test_moe_shard_map_matches_local():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs.base import ArchConfig, MoEConfig
        from repro.models.moe import moe_apply, moe_specs
        from repro.models.layers import init_params
        cfg = ArchConfig(
            name="t", family="moe", num_layers=2, d_model=16, num_heads=4,
            num_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8,
            moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                          capacity_factor=8.0))
        p = init_params(moe_specs(cfg), jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (8, 16, 16), jnp.float32)
        y_local, aux_local = moe_apply(p, cfg, x, mesh=None)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        with mesh:
            y_sh, aux_sh = jax.jit(
                lambda p, x: moe_apply(p, cfg, x, mesh=mesh))(p, x)
        # sharded dispatch routes per-DP-shard: same result when capacity
        # is non-binding
        np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_local),
                                   rtol=3e-3, atol=3e-3)
        print("moe shard_map OK")
    """)


def test_train_step_sharded_matches_single_device():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_arch, reduced
        from repro.models import Model
        from repro.train.loop import make_train_step
        from repro.train.optimizer import optimizer_for, schedule_for
        from repro.distributed.sharding import ShardingPlan
        cfg = reduced(get_arch("llama3.2-3b"))
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        opt = optimizer_for(cfg)
        lr = schedule_for(cfg.name, 1e-3, 100)
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        step0 = jnp.asarray(0, jnp.int32)
        # single device
        sf = make_train_step(model, opt, lr)
        p1, o1, m1 = jax.jit(sf)(params, opt.init(params), batch, step0)
        # 2x4 mesh with the production sharding plan
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        model2 = Model(cfg)
        model2.mesh = mesh
        plan = ShardingPlan(mesh=mesh, fsdp=True, dp_axes=("data",))
        psh = plan.param_shardings(model2.param_logical_axes(),
                                   model2.param_structs())
        sf2 = make_train_step(model2, opt, lr)
        with mesh:
            params_sh = jax.device_put(params, psh)
            p2, o2, m2 = jax.jit(sf2)(params_sh, opt.init(params_sh),
                                      batch, step0)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, \
            (float(m1["loss"]), float(m2["loss"]))
        # updated params agree across the mesh
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=3e-2, atol=3e-2)
        print("sharded train step OK, loss", float(m2["loss"]))
    """)


def test_elastic_restore_across_mesh_shapes():
    """Save a sharded train state on a 2x4 mesh, restore it onto a 4x2
    mesh with different shardings and keep training — the elastic-rescale
    path (node loss -> re-mesh -> resume)."""
    run_py("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.models import Model
        from repro.train.loop import make_train_step
        from repro.train.optimizer import optimizer_for, schedule_for
        from repro.train.checkpoint import save_checkpoint, \
            restore_checkpoint
        from repro.distributed.sharding import ShardingPlan

        cfg = reduced(get_arch("llama3.2-3b"))
        opt = optimizer_for(cfg)
        lr = schedule_for(cfg.name, 1e-3, 100)
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ckpt = tempfile.mkdtemp()

        def setup(shape):
            mesh = Mesh(np.asarray(jax.devices()).reshape(*shape),
                        ("data", "model"))
            model = Model(cfg)
            model.mesh = mesh
            plan = ShardingPlan(mesh=mesh, fsdp=True, dp_axes=("data",))
            psh = plan.param_shardings(model.param_logical_axes(),
                                       model.param_structs())
            return mesh, model, plan, psh

        # train 2 steps on mesh A, checkpoint
        mesh, model, plan, psh = setup((2, 4))
        params = jax.device_put(model.init(jax.random.key(0)), psh)
        state = opt.init(params)
        sf = jax.jit(make_train_step(model, opt, lr))
        with mesh:
            for s in range(2):
                params, state, m = sf(params, state, batch,
                                      jnp.asarray(s, jnp.int32))
        save_checkpoint(ckpt, 2, (params, state))
        loss_a = float(m["loss"])

        # restore onto mesh B (different shape => different shardings)
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh, model, plan, psh = setup((4, 2))
        p0 = model.init(jax.random.key(0))
        osh = {"m": psh, "v": psh,
               "count": NamedSharding(mesh, P())}   # adamw slots
        (params2, state2), step, _ = restore_checkpoint(
            ckpt, (p0, opt.init(p0)), shardings=(psh, osh))
        assert step == 2
        sf = jax.jit(make_train_step(model, opt, lr))
        with mesh:
            params2, state2, m2 = sf(params2, state2, batch,
                                     jnp.asarray(2, jnp.int32))
        assert np.isfinite(float(m2["loss"]))
        print("elastic restore OK: mesh A loss", loss_a,
              "-> mesh B step-3 loss", float(m2["loss"]))
    """)


def test_fleet_sharded_matches_host_oracle():
    """The packed fleet axis auto-shards over an 8-device CPU mesh
    (reconstruction AND streamed attribution) and stays ≤1e-5 of the
    float64 host oracle / identical to the unsharded path."""
    run_py("""
        import numpy as np, jax
        assert jax.device_count() == 8
        from repro.distributed.sharding import fleet_mesh
        from repro.fleet import (FleetStream, fleet_reconstruct,
                                 fleet_reconstruct_host, pack_traces)
        from repro.core.measurement_model import SensorSpec
        from repro.core.sensors import SensorTrace

        rng = np.random.default_rng(0)
        traces = []
        for i in range(16):
            k = 300 - int(rng.integers(0, 40))
            dt = rng.uniform(0.5e-3, 2e-3, k)
            t = np.cumsum(dt); p = rng.uniform(40, 260, k)
            e = np.cumsum(p * dt)
            wb = 24 if i % 2 == 0 else 0
            spec = SensorSpec(name=f"s{i}", scope="chip",
                              kind="energy_cum", quantum=1e-6,
                              wrap_bits=wb)
            if wb:
                e = np.mod(e, (2.0 ** wb) * spec.quantum)
            traces.append(SensorTrace(spec.name, spec, t + 1e-4, t, e))

        packed = pack_traces(traces)
        mesh = fleet_mesh()
        assert mesh is not None and mesh.shape["fleet"] == 8
        power, times, valid = fleet_reconstruct(packed)  # auto-sharded
        p1, _, v1 = fleet_reconstruct(packed, mesh=None)
        ph, th, vh = fleet_reconstruct_host(packed)
        pj, vj = np.asarray(power), np.asarray(valid)
        assert (vj == vh).all() and (vj == np.asarray(v1)).all()
        rel = (np.abs(pj[vj] - ph[vh])
               / np.maximum(np.abs(ph[vh]), 1.0)).max()
        assert rel <= 1e-5, rel
        np.testing.assert_allclose(pj, np.asarray(p1), rtol=1e-6,
                                   atol=1e-5)

        span = float(max(tr.t_measured[-1] for tr in traces))
        edges = np.linspace(0.0, span, 5)
        wins = list(zip(edges[:-1], edges[1:]))
        s_sh = FleetStream(wins, packed.shape[0],
                           wrap_period=packed.wrap_period)   # auto mesh
        s_un = FleetStream(wins, packed.shape[0],
                           wrap_period=packed.wrap_period, mesh=None)
        assert s_sh.mesh is not None
        for lo in range(0, packed.shape[1], 100):
            s_sh.update(packed.times[:, lo:lo + 100],
                        packed.energy[:, lo:lo + 100])
            s_un.update(packed.times[:, lo:lo + 100],
                        packed.energy[:, lo:lo + 100])
        np.testing.assert_allclose(s_sh.totals(), s_un.totals(),
                                   rtol=1e-6, atol=1e-4)
        print("fleet sharding OK")
    """)


def test_fleet_nondivisible_rows_pad_and_stay_sharded():
    """Fleet sizes that do NOT divide the mesh (rows = mesh±1 and the
    8-row pack tile on a 3-device mesh) must pad masked rows up to
    divisibility and KEEP the sharded path — the old fallback silently
    dropped to unsharded execution.  Padded results must equal the
    unsharded path / the float64 host oracle."""
    run_py("""
        import numpy as np, jax
        assert jax.device_count() == 3
        from repro.distributed.sharding import (fleet_mesh,
                                                fleet_row_padding,
                                                fleet_rows_divisible)
        from repro.fleet import (FleetStream, fleet_reconstruct,
                                 fleet_reconstruct_host, pack_traces)
        from repro.core.measurement_model import SensorSpec
        from repro.core.sensors import SensorTrace

        mesh = fleet_mesh()
        assert mesh is not None and mesh.shape["fleet"] == 3
        assert not fleet_rows_divisible(mesh, 8)
        assert fleet_row_padding(mesh, 8) == 1
        assert fleet_row_padding(mesh, 16) == 2

        def make_traces(n):
            rng = np.random.default_rng(5)
            out = []
            for i in range(n):
                k = 260 - int(rng.integers(0, 30))
                dt = rng.uniform(0.5e-3, 2e-3, k)
                t = np.cumsum(dt); p = rng.uniform(40, 260, k)
                e = np.cumsum(p * dt)
                wb = 24 if i % 2 == 0 else 0
                spec = SensorSpec(name=f"s{i}", scope="chip",
                                  kind="energy_cum", quantum=1e-6,
                                  wrap_bits=wb)
                if wb:
                    e = np.mod(e, (2.0 ** wb) * spec.quantum)
                out.append(SensorTrace(spec.name, spec, t + 1e-4, t, e))
            return out

        # reconstruction: 6 traces -> F=8 rows, 3-device mesh -> pad 9
        packed = pack_traces(make_traces(6))
        assert packed.shape[0] == 8
        power, times, valid = fleet_reconstruct(packed)   # auto mesh
        p_un, _, v_un = fleet_reconstruct(packed, mesh=None)
        ph, th, vh = fleet_reconstruct_host(packed)
        pj, vj = np.asarray(power), np.asarray(valid)
        assert pj.shape[0] == 8                  # padding sliced off
        assert (vj == vh).all() and (vj == np.asarray(v_un)).all()
        rel = (np.abs(pj[vj] - ph[vh])
               / np.maximum(np.abs(ph[vh]), 1.0)).max()
        assert rel <= 1e-5, rel
        np.testing.assert_allclose(pj, np.asarray(p_un), rtol=1e-6,
                                   atol=1e-5)

        # streamed attribution at rows = mesh - 1 and mesh + 1
        rng = np.random.default_rng(11)
        for n_rows in (2, 4):
            dt = rng.uniform(0.5e-3, 2e-3, (n_rows, 300))
            t = np.cumsum(dt, axis=1).astype(np.float32)
            p = rng.uniform(40, 260, (n_rows, 300))
            e = np.cumsum(p * dt, axis=1).astype(np.float32)
            span = float(t.max())
            edges = np.linspace(0.0, span, 4)
            wins = list(zip(edges[:-1], edges[1:]))
            s_sh = FleetStream(wins, n_rows)             # auto mesh
            s_un = FleetStream(wins, n_rows, mesh=None)
            assert s_sh.mesh is not None, n_rows
            assert s_sh._attr._row_pad == (-n_rows) % 3, n_rows
            for lo in range(0, 300, 100):
                s_sh.update(t[:, lo:lo + 100], e[:, lo:lo + 100])
                s_un.update(t[:, lo:lo + 100], e[:, lo:lo + 100])
            assert s_sh.totals().shape == (n_rows, 3)
            np.testing.assert_allclose(s_sh.totals(), s_un.totals(),
                                       rtol=1e-6, atol=1e-4)
        print("nondivisible fleet padding OK")
    """, n_devices=3)


def test_dryrun_single_cell_tiny_mesh():
    """The dry-run machinery itself (lower+compile+costs) on a 2x4 mesh."""
    run_py("""
        import numpy as np, jax
        devices = jax.devices()      # pin the 8-device backend BEFORE
        assert len(devices) == 8     # dryrun import rewrites XLA_FLAGS
        import repro.launch.mesh as mesh_mod
        from jax.sharding import Mesh
        # shrink the production mesh for the 8-device test process
        mesh_mod.make_production_mesh = lambda multi_pod=False: Mesh(
            np.asarray(devices).reshape(2, 4), ("data", "model"))
        import repro.launch.dryrun as dr
        dr.make_production_mesh = mesh_mod.make_production_mesh
        rec, compiled = dr.lower_cell("whisper-base", "train_4k")
        assert rec["status"] == "ok", rec
        assert rec["hlo_flops_per_device"] > 0
        assert rec["roofline"]["compute_s"] > 0
        print("dryrun cell OK:", rec["bottleneck"])
    """)
