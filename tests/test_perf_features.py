"""Beyond-paper perf features: f8 KV cache, head-pinning knob, fusion-aware
HLO byte accounting."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import Model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_f8_kv_cache_decode_accuracy(monkeypatch):
    """f8 KV cache must track the bf16-cache decode closely."""
    cfg = reduced(get_arch("llama3.2-3b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)

    def run():
        cache = model.init_cache(1, 32)
        step = jax.jit(model.decode_step)
        lg = None
        for i in range(10):
            lg, cache = step(params, {"tokens": toks[:, i:i + 1]}, cache,
                             jnp.asarray(i, jnp.int32))
        return np.asarray(lg[0, 0], np.float32)

    ref = run()
    monkeypatch.setenv("REPRO_KV_DTYPE", "float8_e4m3fn")
    f8 = run()
    # top-1 greedy decision preserved, logits close in probability space
    assert np.argmax(ref) == np.argmax(f8)
    p_ref = np.exp(ref - ref.max()) / np.exp(ref - ref.max()).sum()
    p_f8 = np.exp(f8 - f8.max()) / np.exp(f8 - f8.max()).sum()
    assert np.abs(p_ref - p_f8).max() < 0.05


def test_hlo_costs_fusion_slice_accounting():
    """A scanned dynamic-slice must charge per-slice bytes, not the whole
    buffer per step (the xlstm 13x correction)."""
    from jax import lax
    from repro.launch.hlo_costs import analyze

    def scanned_slices(big):
        def body(c, i):
            sl = lax.dynamic_slice_in_dim(big, i * 8, 8, axis=0)
            return c + jnp.sum(sl), None
        out, _ = lax.scan(body, jnp.zeros(()), jnp.arange(64))
        return out

    big = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    a = analyze(jax.jit(scanned_slices).lower(big).compile().as_text())
    whole = 512 * 1024 * 4
    # 64 steps x per-slice (8x1024x4) traffic ~ one full pass; the old
    # accounting charged 64 x whole buffer
    assert a["bytes"] < 8 * whole, a["bytes"]


def test_attn_pin_preserves_numerics():
    """Head-pinned sharding is a layout hint only — identical outputs."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = textwrap.dedent("""
        import os, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced
        from repro.models import Model
        cfg = reduced(get_arch("llama3.2-3b"))
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        def run(pin):
            os.environ["REPRO_ATTN_HEAD_CONSTRAINT"] = pin
            model = Model(cfg)
            model.mesh = mesh
            params = model.init(jax.random.key(0))
            with mesh:
                loss, _ = jax.jit(model.forward_train)(params, batch)
            return float(loss)
        a, b = run("0"), run("1")
        assert abs(a - b) < 1e-3, (a, b)
        print("attn_pin numerics OK", a, b)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr


def test_ring_cache_bounds_local_layer_memory():
    cfg = reduced(get_arch("gemma2-27b"))
    model = Model(cfg)
    specs = model.cache_specs(2, 32)
    # pattern = (local, global): pos0 ring-bounded by window, pos1 full
    assert specs["pos0"]["kv"]["k"].shape[2] == cfg.sliding_window
    assert specs["pos1"]["kv"]["k"].shape[2] == 32
