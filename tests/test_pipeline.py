"""Streaming stage pipeline: batch-replay parity, online drift tracking,
chunk sanitization properties, and phase-table padding regression."""
import dataclasses

import numpy as np
import pytest

from repro.core import ToolSpec, simulate_sensor, square_wave
from repro.core.measurement_model import SensorSpec, chip_energy_sensor
from repro.core.sensors import SensorTrace
from repro.fleet import FleetStream, attribute_energy_fused_streaming
from repro.fleet.pipeline import (PHASE_ALIGN, AlignTrackStage,
                                  IngestStage, ReconstructStage,
                                  StreamPipeline, _min_cadence,
                                  pack_stream_rows, pad_phases,
                                  stream_row_windows)


# ------------------------------------------------ batch-replay parity

def _sim_groups(n_devices, seed=0, span_s=4.5, noise=3.0):
    """Per device: a wrapping energy counter + a noisy power sensor,
    distinct configured delays per device (fast 1 ms cadence so replay
    windows stay small)."""
    truth = square_wave(span_s / 4.0, 3, lead_s=span_s / 8,
                        tail_s=span_s / 8)
    tool = ToolSpec(0.9e-3)
    groups = []
    for d in range(n_devices):
        specs = [
            SensorSpec(name=f"d{d}_energy", scope="chip",
                       kind="energy_cum", quantum=1e-6, wrap_bits=26,
                       delay_s=0.004 * (d % 5)),
            SensorSpec(name=f"d{d}_power", scope="chip",
                       kind="power_inst", noise_w=noise, quantum=1e-6,
                       delay_s=0.011 + 0.003 * (d % 3)),
        ]
        groups.append([simulate_sensor(sp, tool, truth,
                                       seed=seed + 31 * d + i)
                       for i, sp in enumerate(specs)])
    return truth, groups


def _parity_phases(grid, n=6):
    edges = np.linspace(float(grid[0]), float(grid[-1]), n + 1)
    return [(f"p{k}", float(a), float(b))
            for k, (a, b) in enumerate(zip(edges[:-1], edges[1:]))]


def _run_parity(n_devices, chunk, span_s=4.5, min_chunks=None):
    from repro.align import align_and_fuse, attribute_energy_fused
    truth, groups = _sim_groups(n_devices, span_s=span_s)
    fused = align_and_fuse(groups, reference=truth)
    grid = fused[0].grid
    d_all = np.concatenate([fs.delays for fs in fused])
    phases = _parity_phases(grid)
    batch = attribute_energy_fused(groups, phases, grid=grid,
                                   delays=d_all)
    if min_chunks is not None:      # the pipeline must really chunk
        flat = [tr for g in groups for tr in g]
        rows = pack_stream_rows(flat)
        n_win = sum(1 for _ in stream_row_windows(rows, chunk))
        assert n_win >= min_chunks, (n_win, min_chunks)
    stream = attribute_energy_fused_streaming(
        groups, phases, grid=grid, delays=d_all, chunk=chunk)
    worst = 0.0
    for rb, rs in zip(batch, stream):
        for pb, ps in zip(rb, rs):
            worst = max(worst, abs(ps.energy_j - pb.energy_j)
                        / max(abs(pb.energy_j), 1.0))
    return worst


def test_streaming_fused_matches_batch_small():
    """Chunked streaming pipeline == batch align_and_fuse ->
    attribute_energy_fused at <=1e-5 (fixed delays, same grid)."""
    worst = _run_parity(2, chunk=257)
    assert worst <= 1e-5, worst


def test_streaming_fused_long_run_parity():
    """The acceptance-scale run: >=64 devices x >=64 chunks, <=1e-5."""
    worst = _run_parity(64, chunk=64, span_s=4.5, min_chunks=64)
    assert worst <= 1e-5, worst


def test_streaming_fused_online_tracking_close_to_batch():
    """With delays estimated ONLINE (sliding windows) instead of fixed,
    the streamed energies stay within ~2% of the batch path."""
    from repro.align import attribute_energy_fused
    truth, groups = _sim_groups(2)
    phases = [("a", 0.8, 1.8), ("b", 2.0, 3.6)]
    batch = attribute_energy_fused(groups, phases, reference=truth)
    stream = attribute_energy_fused_streaming(
        groups, phases, reference=truth, chunk=512, window=1024,
        hop=256, max_lag=64)
    for rb, rs in zip(batch, stream):
        for pb, ps in zip(rb, rs):
            assert abs(ps.energy_j - pb.energy_j) \
                <= 0.02 * max(abs(pb.energy_j), 1.0), pb.phase


def test_streaming_fused_row_count_off_tile():
    """Stream counts that are NOT a multiple of the row tile must pad
    every per-row input (kind_row AND wrap_period) consistently."""
    from repro.align import align_and_fuse, attribute_energy_fused
    truth, groups = _sim_groups(3)        # 6 rows < ROW_ALIGN
    fused = align_and_fuse(groups, reference=truth)
    grid = fused[0].grid
    d_all = np.concatenate([fs.delays for fs in fused])
    phases = _parity_phases(grid, n=3)
    batch = attribute_energy_fused(groups, phases, grid=grid,
                                   delays=d_all)
    stream = attribute_energy_fused_streaming(
        groups, phases, grid=grid, delays=d_all, chunk=512)
    for rb, rs in zip(batch, stream):
        for pb, ps in zip(rb, rs):
            assert abs(ps.energy_j - pb.energy_j) \
                <= 1e-5 * max(abs(pb.energy_j), 1.0), pb.phase
    # direct construction with wrapping counters (serve_demo's shape
    # generalized off the 8-row tile)
    from repro.fleet import StreamingFusedPipeline
    pipe = StreamingFusedPipeline(
        [2] * 3, [(0.0, 1.0)], grid_origin=0.0, grid_step=1e-3,
        kind_row=[True, False] * 3, wrap_period=[67.0, 0.0] * 3,
        delays=np.zeros(6), track=False)
    assert pipe.totals().shape == (3, 1)


def test_power_row_span_opens_at_first_sample():
    """A raw power row's coverage starts at its FIRST sample (batch
    SeriesRows convention); the first inter-sample gap must not be
    masked off in the streamed path."""
    from repro.align import attribute_energy_fused
    truth = square_wave(1.0, 2, lead_s=0.4, tail_s=0.4)
    spec = SensorSpec(name="p0", scope="chip", kind="power_inst",
                      quantum=1e-6)       # delay_s = 0: queries land in
    tr = simulate_sensor(spec, ToolSpec(1e-3), truth, seed=13)
    groups = [[tr]]                       # the opening gap
    t0 = float(tr.t_measured[0])
    phases = [("head", t0, t0 + 0.05), ("rest", t0 + 0.05, t0 + 2.0)]
    grid = np.arange(t0, float(tr.t_measured[-1]), 0.51e-3)
    batch = attribute_energy_fused(groups, phases, grid=grid,
                                   delays=np.zeros(1))
    stream = attribute_energy_fused_streaming(
        groups, phases, grid=grid, delays=np.zeros(1), chunk=256)
    for pb, ps in zip(batch[0], stream[0]):
        assert abs(ps.energy_j - pb.energy_j) \
            <= 1e-5 * max(abs(pb.energy_j), 1.0), pb.phase


# ------------------------------------------------ online drift tracking

def _track_drift(drift_ppm, span=16.0, seed=3):
    truth = square_wave(0.25, int((span - 1.0) / 0.25), lead_s=0.5,
                        tail_s=0.5)
    spec = dataclasses.replace(chip_energy_sensor(0), delay_s=0.005,
                               drift_ppm=drift_ppm)
    tr = simulate_sensor(spec, ToolSpec(1e-3), truth, seed=seed)
    rows = pack_stream_rows([tr])
    step = 0.5 * _min_cadence(rows)     # measured cadence, NOT nominal
    t0 = rows.t0
    align = AlignTrackStage(
        1, grid_step=step,
        reference=lambda t: truth.power_at(t + t0),
        window=4096, hop=1024, max_lag=40, ema=0.5)
    pipe = StreamPipeline(IngestStage(rows.shape[0], mode="sanitize"),
                          ReconstructStage(rows.kind_row), align)
    for t_blk, v_blk in stream_row_windows(rows, 1024):
        pipe.update(t_blk, v_blk)
    return truth, spec, tr, rows, align


def test_aligntrack_follows_200ppm_drift():
    """The tracked delay stays within 0.5x the sensor update interval of
    the drifting ground truth AT EVERY WINDOW (acceptance criterion),
    while a whole-trace batch estimate can only see the mid-run
    average."""
    drift = 200.0
    truth, spec, tr, rows, align = _track_drift(drift)
    interval = spec.production_interval_s
    assert len(align.history) >= 8
    for p in align.history:
        true_d = spec.delay_s \
            + (p.t_center + rows.t0 - truth.t0) * drift * 1e-6
        assert abs(p.ema[0] - true_d) <= 0.5 * interval, \
            (p.t_center, p.ema[0], true_d)
    # total drift over the run is several intervals — tracking matters
    total_drift = (truth.t1 - truth.t0) * drift * 1e-6
    assert total_drift > 2.5 * interval
    # batch xcorr over the whole trace: pinned to the mid-run AVERAGE
    from repro.align import (estimate_delays, regrid_rows,
                             schedule_reference, series_rows_from_traces)
    from repro.align.fusion import default_grid
    sr = series_rows_from_traces([tr])
    grid, gstep = default_grid(sr)
    vals, mask = regrid_rows(sr, grid)
    est = estimate_delays(vals, mask, schedule_reference(truth, grid),
                          step=gstep, max_lag=64)
    mid = spec.delay_s + 0.5 * (truth.t1 - truth.t0) * drift * 1e-6
    end = spec.delay_s + (truth.t1 - truth.t0) * drift * 1e-6
    assert abs(est.delay_s[0] - mid) <= 0.5 * interval
    assert end - est.delay_s[0] > 0.4 * total_drift   # misses the end lag
    # ... while the online tracker's LAST window sits near the end truth
    last = align.history[-1]
    last_truth = spec.delay_s \
        + (last.t_center + rows.t0 - truth.t0) * drift * 1e-6
    assert abs(last.ema[0] - last_truth) <= 0.5 * interval
    assert last.ema[0] - est.delay_s[0] > 0.25 * total_drift


def test_drift_zero_is_bit_identical():
    """drift_ppm defaults to 0 and leaves the simulator untouched."""
    truth = square_wave(1.0, 2, lead_s=0.3, tail_s=0.3)
    a = simulate_sensor(chip_energy_sensor(0), ToolSpec(1e-3), truth,
                        seed=3)
    b = simulate_sensor(dataclasses.replace(chip_energy_sensor(0),
                                            drift_ppm=0.0),
                        ToolSpec(1e-3), truth, seed=3)
    np.testing.assert_array_equal(a.value, b.value)
    np.testing.assert_array_equal(a.t_measured, b.t_measured)


def test_drift_shifts_only_timestamps():
    """At the production stage, drift stretches the reported clock
    linearly and leaves the measured values bit-identical."""
    from repro.core.sensors import produce
    truth = square_wave(1.0, 2, lead_s=0.3, tail_s=0.3)
    spec0 = chip_energy_sensor(0)
    spec1 = dataclasses.replace(spec0, drift_ppm=500.0)
    tm0, v0 = produce(spec0, truth, np.random.default_rng(9))
    tm1, v1 = produce(spec1, truth, np.random.default_rng(9))
    np.testing.assert_array_equal(v0, v1)
    # reported clock: tm + (tm_true - t0) * ppm; the tiny timestamp
    # jitter enters both paths identically, so the difference IS the
    # drift term (up to jitter * ppm ~ 1e-8)
    drift_term = tm1 - tm0
    assert np.all(drift_term >= 0)
    np.testing.assert_allclose(drift_term,
                               (tm0 - truth.t0) * 500e-6, atol=1e-6)


# ------------------------------------------------ sanitize property

def test_sanitize_chunk_conserves_energy_property():
    """Arbitrary reordered/duplicated timestamp permutations: the
    streamed total over the full span equals the clean trace's dE, for
    ANY chunking (the carry bridges chunk boundaries)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def perturbed(draw):
        n = draw(st.integers(12, 80))
        rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
        dt = rng.uniform(0.5e-3, 2e-3, n)
        t = np.cumsum(dt)
        p = rng.uniform(40.0, 260.0, n)
        e = np.cumsum(p * dt)
        # duplicated reads: repeat random positions
        reps = draw(st.integers(0, 3))
        idx = np.sort(np.concatenate(
            [np.arange(n), rng.integers(0, n, reps)]))
        # reorder episodes: swap a few adjacent index pairs
        swaps = draw(st.integers(0, 3))
        for _ in range(swaps):
            j = int(rng.integers(1, len(idx) - 1))
            idx[j - 1], idx[j] = idx[j], idx[j - 1]
        split = draw(st.integers(1, len(idx) - 1))
        return t, e, idx, split

    @given(perturbed())
    @settings(max_examples=30, deadline=None)
    def inner(case):
        t, e, idx, split = case
        tt, ee = t[idx], e[idx]
        span = [(0.0, float(t[-1]) + 1e-3)]
        one = FleetStream(span, 1)
        one.update(tt[None, :], ee[None, :])
        two = FleetStream(span, 1)
        two.update(tt[None, :split], ee[None, :split])
        two.update(tt[None, split:], ee[None, split:])
        # the running-max keep-set is chunking-invariant, so totals
        # must agree exactly up to float accumulation order
        np.testing.assert_allclose(one.totals(), two.totals(),
                                   rtol=1e-5, atol=1e-4)
        # and conserve the kept subsequence's dE exactly
        keep_e = ee[tt >= np.maximum.accumulate(
            np.concatenate([[-np.inf], tt[:-1]]))]
        expect = float(keep_e[-1] - keep_e[0])
        total = float(one.totals()[0, 0])
        assert abs(total - expect) <= 1e-3 * max(abs(expect), 1.0) + 1e-2

    inner()


# ------------------------------------------------ pad_phases regression

def test_pad_phases_always_rounds_up_to_tile():
    for p in (1, 2, 5, 31, 32, 33, 48, 64):
        ph = pad_phases([(0.0, float(i + 1)) for i in range(p)])
        assert len(ph) % PHASE_ALIGN == 0 and len(ph) >= p, (p, len(ph))
        # padding windows are zero-width -> integrate to exactly zero
        assert (ph[p:, 0] == ph[p:, 1]).all()


@pytest.mark.parametrize("n_phases", [2, 5, 31])
def test_small_phase_counts_through_kernel(n_phases):
    """1 < p < 32 phase tables stream through the fused kernel padded to
    the full tile and match the per-trace host attribution (the
    pre-pipeline pad_phases only padded p > 32)."""
    from repro.core import attribute_energy
    rng = np.random.default_rng(7)
    k = 400
    dt = rng.uniform(0.5e-3, 2e-3, k)
    t = np.cumsum(dt)
    p = rng.uniform(40.0, 260.0, k)
    e = np.cumsum(p * dt)
    spec = SensorSpec(name="s", scope="chip", kind="energy_cum",
                      quantum=1e-6)
    tr = SensorTrace("s", spec, t + 1e-4, t, e)
    edges = np.linspace(float(t[0]), float(t[-1]), n_phases + 1)
    phases = [(f"p{j}", float(a), float(b))
              for j, (a, b) in enumerate(zip(edges[:-1], edges[1:]))]
    stream = FleetStream([(a, b) for _, a, b in phases], 1)
    assert stream.phases.shape[0] % PHASE_ALIGN == 0
    for lo in range(0, k, 128):
        stream.update(t[None, lo:lo + 128], e[None, lo:lo + 128])
    host = attribute_energy(tr, phases)
    got = stream.totals()[0]
    assert got.shape == (n_phases,)
    for h, g in zip(host, got):
        assert abs(g - h.energy_j) <= 1e-3 * max(abs(h.energy_j), 1.0), \
            h.phase


# ------------------------------------------------ hpl / consumers

def test_fused_streaming_hpl_energize_close_to_batch():
    import time
    from repro.core.tracing import RegionTracer
    from repro.hpl.energy import fused_fleet_energize
    tracer = RegionTracer()
    with tracer.region("hpl_factorize"):
        time.sleep(0.6)
    with tracer.region("hpl_solve"):
        time.sleep(0.5)
    batch = fused_fleet_energize(tracer, 1)
    stream = fused_fleet_energize(tracer, 1, streaming=True, chunk=512)
    for rb, rs in zip(batch, stream):
        for pb, ps in zip(rb, rs):
            assert pb.phase == ps.phase
            assert abs(ps.energy_j - pb.energy_j) \
                <= 0.05 * max(abs(pb.energy_j), 1.0), pb.phase


def test_ingest_maskfill_matches_accumulator_semantics():
    """The pipeline Ingest(maskfill) must keep the accumulator's
    invalid-first-slot behavior (zero-width seed at the first VALID
    sample)."""
    from repro.fleet import StreamingPhaseAccumulator
    t = np.array([[0.0, 100.0, 100.1, 100.2, 100.3]], np.float32)
    w = np.array([[999.0, 50.0, 50.0, 50.0, 50.0]], np.float32)
    valid = np.array([[False, True, True, True, True]])
    acc = StreamingPhaseAccumulator([(0.0, 200.0)], 1)
    acc.update(t, w, valid=valid)
    e = float(acc.totals()[0, 0])
    assert abs(e - 50.0 * 0.3) < 1e-3, e
