"""Checkpointable pipeline carries: kill/resume bit-parity and the
carry round-trip property (single host; the elastic multi-process side
lives in tests/multihost/test_elastic.py).

The acceptance oracle is the carry-checkpoint determinism rule: every
stage carry is exact state of a float64 left fold, so restoring it and
replaying the remaining windows must reproduce the uninterrupted run's
fused per-phase energies BIT-identically — not approximately.
"""
import numpy as np
import pytest

from multihost.simdata import (energy_matrix, shared_grid_and_phases,
                               sim_groups)
from repro.fleet import DataQualityError, DataQualityPolicy
from repro.fleet.pipeline import attribute_energy_fused_streaming

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # container has no hypothesis:
    HAVE_HYPOTHESIS = False           # fall back to a seeded sweep


class _Kill(Exception):
    pass


def _killer(at):
    def hook(pipe, w):
        if w == at:
            raise _Kill
    return hook


def _run(groups, grid, phases, truth=None, delays=None, **kw):
    track = truth is not None
    return energy_matrix(attribute_energy_fused_streaming(
        groups, phases, grid=grid, delays=delays,
        reference=truth if track else None, track=track,
        window=512, hop=128, **kw))


@pytest.mark.parametrize("tracked", [False, True],
                         ids=["fixed-delays", "tracked"])
def test_kill_resume_bit_identical(tmp_path, tracked):
    """Kill at window 7 (checkpoint cadence 3 -> resumes from 6): the
    resumed run's energies equal the uninterrupted run's to the BIT,
    for both fixed-delay and online-tracked pipelines."""
    truth, groups, delays = sim_groups(3)
    grid, phases = shared_grid_and_phases(groups)
    kw = (dict(truth=truth) if tracked
          else dict(delays=delays))
    base = _run(groups, grid, phases, chunk=257, **kw)
    with pytest.raises(_Kill):
        _run(groups, grid, phases, chunk=257, checkpoint_dir=tmp_path,
             checkpoint_every=3, on_window=_killer(7), **kw)
    resumed = _run(groups, grid, phases, chunk=257,
                   checkpoint_dir=tmp_path, resume=True, **kw)
    np.testing.assert_array_equal(resumed, base)


def test_kill_resume_with_health_stage(tmp_path):
    """The health state machine (streaks, EMAs, pending stats block)
    checkpoints too: a resumed health-enabled run stays bit-identical."""
    truth, groups, delays = sim_groups(3)
    grid, phases = shared_grid_and_phases(groups)
    base = _run(groups, grid, phases, delays=delays, chunk=257,
                health=True)
    with pytest.raises(_Kill):
        _run(groups, grid, phases, delays=delays, chunk=257, health=True,
             checkpoint_dir=tmp_path, checkpoint_every=2,
             on_window=_killer(7))
    resumed = _run(groups, grid, phases, delays=delays, chunk=257,
                   health=True, checkpoint_dir=tmp_path, resume=True)
    np.testing.assert_array_equal(resumed, base)


def test_resume_without_checkpoint_is_cold_start(tmp_path):
    """resume=True against an empty dir runs from scratch (the restart
    wrapper always passes resume=True; first boot has nothing saved)."""
    truth, groups, delays = sim_groups(2)
    grid, phases = shared_grid_and_phases(groups)
    base = _run(groups, grid, phases, delays=delays, chunk=257)
    resumed = _run(groups, grid, phases, delays=delays, chunk=257,
                   checkpoint_dir=tmp_path / "empty", resume=True)
    np.testing.assert_array_equal(resumed, base)


def test_restore_refuses_config_mismatch(tmp_path):
    """A checkpoint from a differently-shaped pipeline must be
    rejected, not silently misinterpreted."""
    truth, groups, delays = sim_groups(2)
    grid, phases = shared_grid_and_phases(groups)
    with pytest.raises(_Kill):
        _run(groups, grid, phases, delays=delays, chunk=257,
             checkpoint_dir=tmp_path, checkpoint_every=3,
             on_window=_killer(4))
    with pytest.raises(AssertionError, match="config mismatch"):
        _run(groups, grid, phases[:3], delays=delays, chunk=257,
             checkpoint_dir=tmp_path, resume=True)


def _roundtrip_property(seed: int):
    """Randomized carry states: simulate a random fleet, kill at a
    random window past the first checkpoint, resume — bit parity."""
    rng = np.random.default_rng(seed)
    n_devices = int(rng.integers(1, 4))
    chunk = int(rng.choice([101, 173, 257]))
    every = int(rng.integers(1, 4))
    noise = float(rng.uniform(0.5, 6.0))
    truth, groups, delays = sim_groups(n_devices, seed=seed,
                                       span_s=1.5, noise=noise)
    grid, phases = shared_grid_and_phases(groups, n_phases=4)
    base = _run(groups, grid, phases, delays=delays, chunk=chunk)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        kill_at = every + int(rng.integers(1, 5))
        try:
            _run(groups, grid, phases, delays=delays, chunk=chunk,
                 checkpoint_dir=d, checkpoint_every=every,
                 on_window=_killer(kill_at))
            return          # replay shorter than the kill window: done
        except _Kill:
            pass
        resumed = _run(groups, grid, phases, delays=delays, chunk=chunk,
                       checkpoint_dir=d, resume=True)
    np.testing.assert_array_equal(resumed, base)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_checkpoint_roundtrip_property(seed):
        _roundtrip_property(seed)
else:
    @pytest.mark.parametrize("seed", [0, 7, 19, 42, 1234, 99991])
    def test_checkpoint_roundtrip_property(seed):
        _roundtrip_property(seed)


# ---------------------------------------------------------------------------
# Data-quality policies (tentpole part 3)
# ---------------------------------------------------------------------------

def _live_pipe(policy=None):
    """A tiny 2-power-stream pipeline driven by raw update() chunks —
    the live-ingest path where reordered/dropped samples actually
    arrive out of order (trace replay flattens them at pack time)."""
    from repro.fleet.pipeline import StreamingFusedPipeline
    return StreamingFusedPipeline(
        [2], [(0.0, 1.0)], grid_origin=0.0, grid_step=0.01,
        delays=np.zeros(2), track=False, dq_policy=policy)


def test_dq_late_samples_counted_on_live_ingest():
    pipe = _live_pipe(DataQualityPolicy())
    t1 = np.array([[0.00, 0.01, 0.02, 0.03]] * 2)
    v1 = np.full((2, 4), 100.0)
    pipe.update(t1, v1)
    # row 0 delivers one reordered read (0.015 after 0.04)
    t2 = np.array([[0.04, 0.015, 0.05, 0.06],
                   [0.04, 0.045, 0.05, 0.06]])
    pipe.update(t2, np.full((2, 4), 100.0))
    late = pipe.ingest.dq_late[:2]
    assert late[0] == 1 and late[1] == 0
    assert pipe.ingest.dq_last["late"][0] == 1


def test_dq_dropped_samples_counted_from_valid_mask():
    pipe = _live_pipe(DataQualityPolicy())
    t = np.array([[0.00, 0.01, 0.02, 0.03]] * 2)
    valid = np.ones((2, 4), bool)
    valid[1, 2] = False
    pipe.update(t, np.full((2, 4), 100.0), valid)
    assert pipe.ingest.dq_masked[:2].tolist() == [0, 1]


def test_dq_policy_raise_on_late_and_dropped():
    pipe = _live_pipe(DataQualityPolicy(late="raise"))
    pipe.update(np.array([[0.00, 0.01]] * 2), np.full((2, 2), 1.0))
    with pytest.raises(DataQualityError, match="late/reordered"):
        pipe.update(np.array([[0.02, 0.005], [0.02, 0.025]]),
                    np.full((2, 2), 1.0))
    pipe = _live_pipe(DataQualityPolicy(dropped="raise"))
    bad = np.ones((2, 2), bool)
    bad[0, 1] = False
    with pytest.raises(DataQualityError, match="dropped"):
        pipe.update(np.array([[0.00, 0.01]] * 2),
                    np.full((2, 2), 1.0), bad)


def test_dq_policy_coverage_flag_and_raise():
    """A sensor that stops publishing mid-run drops its window
    coverage: the flag policy surfaces it, the raise policy aborts."""
    import dataclasses
    truth, groups, delays = sim_groups(2, span_s=1.5)
    groups = [list(g) for g in groups]
    tr = groups[1][1]
    n_keep = len(tr.t_measured) // 3   # ends at 1/3 of the span
    groups[1][1] = dataclasses.replace(
        tr, t_measured=tr.t_measured[:n_keep].copy(),
        t_read=tr.t_read[:n_keep].copy(),
        value=tr.value[:n_keep].copy())
    grid, phases = shared_grid_and_phases(groups, n_phases=4)
    # the dead sensor stalls the emit frontier, so other rows pile up
    # samples until the flush: a wide tail keeps them all answerable
    out, pipe = attribute_energy_fused_streaming(
        groups, phases, grid=grid, delays=delays, chunk=257, tail=4096,
        dq_policy=DataQualityPolicy(min_coverage=0.9), return_pipe=True)
    assert pipe.fuse.dq_low_coverage[3]     # row 3 = device 1's power
    assert pipe.fuse.dq_last_coverage[3] < 0.9
    with pytest.raises(DataQualityError, match="min_coverage"):
        attribute_energy_fused_streaming(
            groups, phases, grid=grid, delays=delays, chunk=257,
            tail=4096,
            dq_policy=DataQualityPolicy(min_coverage=0.9,
                                        coverage="raise"))


def test_dq_registry_source_exports_flags():
    from repro.health.registry import HealthRegistry
    truth, groups, delays = sim_groups(2, span_s=1.5)
    grid, phases = shared_grid_and_phases(groups, n_phases=4)
    reg = HealthRegistry()
    attribute_energy_fused_streaming(
        groups, phases, grid=grid, delays=delays, chunk=257,
        dq_policy=DataQualityPolicy(), registry=reg)
    names = {m.name for m in reg.collect()}
    assert {"ingest_late_samples_total", "ingest_dropped_samples_total",
            "window_coverage_frac", "dq_flag"} <= names


def test_dq_policy_validates_fields():
    with pytest.raises(AssertionError):
        DataQualityPolicy(late="explode")
    with pytest.raises(AssertionError):
        DataQualityPolicy(min_coverage=1.5)
