"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep: skip, don't error

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import PiecewisePower, square_wave, unwrap_counter
from repro.core.power_model import occupancy_power
from repro.core.reconstruction import PowerSeries


@st.composite
def piecewise(draw):
    n = draw(st.integers(2, 30))
    steps = draw(st.lists(st.floats(1e-3, 2.0), min_size=n, max_size=n))
    watts = draw(st.lists(st.floats(0.0, 500.0), min_size=n, max_size=n))
    times = np.concatenate([[0.0], np.cumsum(steps)])
    return PiecewisePower(times, np.asarray(watts))


@given(piecewise(), st.floats(0.0, 1.0), st.floats(0.0, 1.0),
       st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_energy_additivity(pp, f1, f2, f3):
    """∫[a,c] = ∫[a,b] + ∫[b,c] for any a<=b<=c."""
    span = pp.t1 - pp.t0
    pts = sorted([pp.t0 + f * span for f in (f1, f2, f3)])
    a, b, c = pts
    e_ac = pp.energy_between(a, c)
    e_ab = pp.energy_between(a, b)
    e_bc = pp.energy_between(b, c)
    assert abs(e_ac - (e_ab + e_bc)) < 1e-6 * max(abs(e_ac), 1.0) + 1e-9


@given(piecewise())
@settings(max_examples=40, deadline=None)
def test_energy_bounds(pp):
    """min(P)*T <= E <= max(P)*T."""
    e = pp.energy_between(pp.t0, pp.t1)
    t = pp.t1 - pp.t0
    assert pp.watts.min() * t - 1e-6 <= e <= pp.watts.max() * t + 1e-6


@given(st.integers(4, 12), st.integers(10, 400), st.floats(0.5, 100.0))
@settings(max_examples=40, deadline=None)
def test_unwrap_inverse(bits, n, rate):
    rng = np.random.default_rng(bits * n)
    inc = rng.uniform(0, rate, n)
    period = 2.0 ** bits
    # keep increments below half a period (unwrap precondition)
    inc = np.minimum(inc, 0.45 * period)
    true = np.cumsum(inc)
    wrapped = np.mod(true, period)
    rec = unwrap_counter(wrapped, bits, 1.0)
    np.testing.assert_allclose(rec, true, atol=1e-6 * max(true.max(), 1.0))


@given(st.floats(0.0, 10.0), st.floats(0.0, 10.0), st.floats(0.0, 10.0))
@settings(max_examples=60, deadline=None)
def test_occupancy_power_bounds(c, m, x):
    p = occupancy_power(c, m, x)
    assert 55.0 - 1e-9 <= p <= 215.0 + 1e-9
    # bottleneck unit at meaningful duty: power strictly above idle
    if max(c, m, x) > 1e-3:
        assert p > 55.0


@given(st.integers(2, 50), st.floats(1e-4, 1e-2))
@settings(max_examples=30, deadline=None)
def test_powerseries_energy_consistency(n, dt):
    rng = np.random.default_rng(n)
    t = np.cumsum(np.full(n, dt))
    w = rng.uniform(0, 300, n)
    s = PowerSeries(t, w)
    total = s.energy_between(t[0], t[-1])
    manual = float(np.sum(w[1:] * dt))
    assert abs(total - manual) < 1e-6 * max(manual, 1.0) + 1e-9


@given(st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_square_wave_energy_exact(n_cycles):
    sw = square_wave(2.0, n_cycles, lead_s=1.0, tail_s=1.0)
    e = sw.energy_between(sw.t0, sw.t1)
    expect = (2.0 + n_cycles) * 55.0 + n_cycles * 215.0
    assert abs(e - expect) < 1e-6
