"""Hypothesis property tests on the collective wire format codecs."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep: skip, don't error

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.trace_format import (bitpack, bitunpack, delta_decode,
                                     delta_encode, varint_decode,
                                     varint_encode, zigzag_decode,
                                     zigzag_encode)
from repro.distributed.compression import (MIN_FRAME_BYTES,
                                           decode_reduce_frame,
                                           encode_reduce_frame)

i64 = st.integers(-(2**63), 2**63 - 1)
f64 = st.floats(allow_nan=False, width=64)


@given(st.lists(i64, max_size=64))
@settings(max_examples=80, deadline=None)
def test_prop_zigzag_delta_roundtrip(xs):
    v = np.asarray(xs, np.int64)
    np.testing.assert_array_equal(zigzag_decode(zigzag_encode(v)), v)
    # exact even when diffs wrap: both diff and cumsum are mod 2^64
    with np.errstate(over="ignore"):
        np.testing.assert_array_equal(delta_decode(delta_encode(v)), v)


@given(st.integers(0, 2**64 - 1))
@settings(max_examples=80, deadline=None)
def test_prop_varint_roundtrip(n):
    val, off = varint_decode(varint_encode(n))
    assert val == n


@given(st.integers(1, 64), st.lists(st.integers(0, 2**64 - 1),
                                    max_size=40))
@settings(max_examples=80, deadline=None)
def test_prop_bitpack_roundtrip(bits, xs):
    v = np.asarray(xs, np.uint64)
    if bits < 64:
        v = v & np.uint64((1 << bits) - 1)
    np.testing.assert_array_equal(
        bitunpack(bitpack(v, bits), bits, v.size), v)


@given(f64, st.lists(f64, max_size=48), st.data())
@settings(max_examples=100, deadline=None)
def test_prop_frame_roundtrip(scalar, xs, data):
    v = np.asarray(xs, np.float64)
    # sprinkle zeros so both sparse and dense paths get exercised
    if v.size:
        k = data.draw(st.integers(0, v.size))
        idx = data.draw(st.permutations(range(v.size)))[:k]
        v[np.asarray(idx, np.int64)] = 0.0
    frame = encode_reduce_frame(scalar, v)
    assert len(frame) >= MIN_FRAME_BYTES
    s, out = decode_reduce_frame(frame)
    np.testing.assert_array_equal(np.float64(s), np.float64(scalar))
    np.testing.assert_array_equal(out, np.where(v == 0.0, 0.0, v))
