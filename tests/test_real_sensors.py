"""Real-counter e2e: RAPL / hwmon -> attribute_live on THIS host.

These tests exercise the real ``/sys/class/powercap`` and
``/sys/class/hwmon`` adapters end to end — discovery, prioritized
reads, the async pump, and the full streaming attribution chain — and
skip cleanly on hosts (most CI runners, containers) where the kernel
exposes no readable counter.  The CI ``real-sensors`` job runs them
after best-effort ``chmod a+r`` on the powercap tree.
"""
import glob

import numpy as np
import pytest


def _readable(pattern):
    for p in glob.glob(pattern):
        try:
            with open(p) as f:
                f.read()
            return True
        except OSError:
            continue
    return False


HAVE_RAPL = _readable("/sys/class/powercap/*/energy_uj")
HAVE_HWMON = (_readable("/sys/class/hwmon/hwmon*/energy*_input")
              or _readable("/sys/class/hwmon/hwmon*/power*_input"))

pytestmark = pytest.mark.skipif(
    not (HAVE_RAPL or HAVE_HWMON),
    reason="no readable /sys powercap or hwmon counters on this host")


def _backends():
    from repro.ingest import discover_backends
    return discover_backends(include=["rapl", "hwmon"])


def test_real_backends_declare_counter_semantics():
    backends = _backends()
    if not backends:
        pytest.skip("powercap/hwmon present but discovered no metric")
    for b in backends:
        for sp in b.discover():
            r = b.read(sp.metric)
            assert np.isfinite(r.value) and r.value >= 0.0
            if sp.is_cumulative:
                # the invariant: the KERNEL-declared wrap range rides
                # on the spec — nothing downstream infers it
                assert sp.wrap_range_j > 0.0, sp.metric


def test_real_counters_attribute_nonzero_energy():
    """Half a second of live capture on a running host must attribute
    strictly positive energy from at least one cumulative counter."""
    from repro.ingest import attribute_live
    backends = _backends()
    if not backends:
        pytest.skip("powercap/hwmon present but discovered no metric")
    res = attribute_live(duration_s=0.5, backends=backends, chunk=8,
                         interval_s=0.02, grid_step=0.005, window=32,
                         hop=16, max_lag=4, tail=16)
    assert res.totals.shape == (len(res.groups), 1)
    assert np.all(np.isfinite(res.totals))
    cumulative = [res.ingest.spec(m).is_cumulative
                  for m in res.metrics]
    if any(cumulative):
        assert float(res.totals.sum()) > 0.0, res.energies()
    else:                               # power-only hosts: >= 0 joules
        assert float(res.totals.sum()) >= 0.0
    # provenance rode along: pump flushed and no reader starved
    assert res.pump.n_chunks >= 1
    assert sum(r.n_unavailable for r in res.readers) == 0
