"""Fused-scan engine: the whole streaming replay as one jitted scan.

The per-window stage chain (``StreamingFusedPipeline``) is the parity
oracle: ``engine="scan"`` must reproduce its energies to <=1e-5 in both
the untracked (fixed delays, pinned grid — where the chain itself is
pinned to batch replay) and tracked (online delay estimation) modes,
across chunk sizes, group shapes and grid choices.
"""
import numpy as np
import pytest

from repro.core import ToolSpec, simulate_sensor, square_wave
from repro.core.measurement_model import SensorSpec
from repro.fleet import attribute_energy_fused_streaming
from repro.fleet.pipeline import (ScanResult, attribute_totals_fused_scan,
                                  pack_stream_rows)


def _sim_groups(n_devices, seed=0, span_s=3.0, noise=3.0):
    truth = square_wave(span_s / 4.0, 3, lead_s=span_s / 8,
                        tail_s=span_s / 8)
    tool = ToolSpec(0.9e-3)
    groups = []
    for d in range(n_devices):
        specs = [
            SensorSpec(name=f"d{d}_energy", scope="chip",
                       kind="energy_cum", quantum=1e-6, wrap_bits=26,
                       delay_s=0.004 * (d % 5)),
            SensorSpec(name=f"d{d}_power", scope="chip",
                       kind="power_inst", noise_w=noise, quantum=1e-6,
                       delay_s=0.011 + 0.003 * (d % 3)),
        ]
        groups.append([simulate_sensor(sp, tool, truth,
                                       seed=seed + 31 * d + i)
                       for i, sp in enumerate(specs)])
    return truth, groups


def _worst(rows_a, rows_b):
    worst = 0.0
    for ra, rb in zip(rows_a, rows_b):
        for pa, pb in zip(ra, rb):
            worst = max(worst, abs(pa.energy_j - pb.energy_j)
                        / max(abs(pb.energy_j), 1.0))
    return worst


def _both(groups, phases, chunk, **kw):
    win = attribute_energy_fused_streaming(
        groups, phases, chunk=chunk, engine="windowed", **kw)
    scan = attribute_energy_fused_streaming(
        groups, phases, chunk=chunk, engine="scan", **kw)
    return win, scan


def _pinned(groups, truth):
    from repro.align import align_and_fuse
    fused = align_and_fuse(groups, reference=truth)
    grid = fused[0].grid
    d_all = np.concatenate([fs.delays for fs in fused])
    edges = np.linspace(float(grid[0]), float(grid[-1]), 7)
    phases = [(f"p{k}", float(a), float(b))
              for k, (a, b) in enumerate(zip(edges[:-1], edges[1:]))]
    return grid, d_all, phases


@pytest.mark.parametrize("chunk", [193, 512])
def test_scan_matches_windowed_untracked(chunk):
    """Fixed delays + pinned grid (the replay-parity configuration):
    scan == per-window chain to <=1e-5."""
    truth, groups = _sim_groups(2)
    grid, d_all, phases = _pinned(groups, truth)
    win, scan = _both(groups, phases, chunk, grid=grid, delays=d_all,
                      track=False)
    assert _worst(scan, win) <= 1e-5


def test_scan_matches_windowed_tracked():
    """Online delay tracking against a known reference: the scan's
    host-replayed tracker must hand the SAME per-window delay vectors
    to the regrid, so energies agree to <=1e-5."""
    truth, groups = _sim_groups(2)
    grid, _, phases = _pinned(groups, truth)
    win, scan = _both(groups, phases, 256, grid=grid, reference=truth,
                      track=True, window=512, hop=128)
    assert _worst(scan, win) <= 1e-5


def test_scan_matches_windowed_selfref_default_grid():
    """No reference, no pinned grid: per-group self-reference tracking
    on the derived default grid still agrees to <=1e-5."""
    _, groups = _sim_groups(2, seed=5)
    phases = [("a", 0.6, 1.4), ("b", 1.6, 2.6)]
    win, scan = _both(groups, phases, 256, track=True, window=512,
                      hop=128)
    assert _worst(scan, win) <= 1e-5


def test_scan_unequal_group_sizes():
    """Padded (device, k_max) gathers: group sizes 1/3/2 must not leak
    padding rows into the fusion statistics or pattern integrals."""
    import dataclasses
    span = 2.5
    truth = square_wave(span / 4.0, 3, lead_s=span / 8, tail_s=span / 8)
    tool = ToolSpec(0.9e-3)
    sizes = [1, 3, 2]
    groups, i = [], 0
    for d, sz in enumerate(sizes):
        grp = []
        for j in range(sz):
            kind = "energy_cum" if j % 2 == 0 else "power_inst"
            sp = SensorSpec(name=f"d{d}_{j}", scope="chip", kind=kind,
                            quantum=1e-6,
                            wrap_bits=26 if kind == "energy_cum" else 0,
                            noise_w=0.0 if kind == "energy_cum" else 3.0,
                            delay_s=0.002 * (i % 7))
            tr = simulate_sensor(sp, tool, truth, seed=100 + 17 * i)
            grp.append(dataclasses.replace(tr))
            i += 1
        groups.append(grp)
    grid, d_all, phases = _pinned(groups, truth)
    win, scan = _both(groups, phases, 200, grid=grid, delays=d_all,
                      track=False)
    assert _worst(scan, win) <= 1e-5


def test_scan_result_surface():
    """attribute_totals_fused_scan returns the full ScanResult: totals,
    end-of-run IVW weights, final delays and the tracker history."""
    truth, groups = _sim_groups(2, seed=9)
    flat = [tr for g in groups for tr in g]
    rows = pack_stream_rows(flat)
    origin = float(rows.times[:rows.n_streams, 0].astype(np.float64)
                   .min())
    phases = [(0.6 - rows.t0, 1.4 - rows.t0), (1.6 - rows.t0,
                                               2.6 - rows.t0)]
    t0 = rows.t0
    res = attribute_totals_fused_scan(
        rows, [2, 2], phases, grid_origin=origin, grid_step=5e-4,
        chunk=256, reference=lambda t: truth.power_at(t + t0),
        track=True, window=512, hop=128)
    assert isinstance(res, ScanResult)
    assert res.totals.shape == (2, 2)
    assert res.weights.shape == (4,) and (res.weights > 0).all()
    assert res.delays.shape == (4,)
    assert res.n_steps > 0 and res.n_slots > 0
    assert len(res.history) > 0        # the tracker fired
    # configured delays recovered within a grid step or two
    want = np.asarray([0.004 * (d % 5) for d in range(2)
                       for _ in range(1)])
    got = res.delays[::2]              # the energy rows
    assert np.all(np.abs(got - want) <= 2e-3), (got, want)


def test_scan_engine_rejects_unknown_engine():
    _, groups = _sim_groups(1)
    with pytest.raises(AssertionError):
        attribute_energy_fused_streaming(
            groups, [("a", 0.5, 1.0)], engine="warp")
