"""Continuous-batching serve engine + per-request energy metering."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model

_CACHE = {}


def _setup(arch="llama3.2-3b"):
    if arch not in _CACHE:
        cfg = reduced(get_arch(arch))
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        _CACHE[arch] = (cfg, model, params)
    return _CACHE[arch]


def _reqs(cfg, lens, max_new, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=(ln,)).astype(np.int32),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(zip(lens, max_new))]


# ---------------------------------------------------------------------------
# greedy parity: continuous batching == fixed-batch serve-to-completion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma2-27b"])
def test_continuous_matches_fixed_batch(arch):
    from repro.serve import FixedBatchEngine, ServeEngine
    cfg, model, params = _setup(arch)
    lens = [6, 6, 6, 6]                 # equal lengths: no padding skew
    max_new = [7, 3, 5, 2]
    fixed = FixedBatchEngine(model, params, batch_slots=2, max_len=32)
    out_f = fixed.run(_reqs(cfg, lens, max_new))
    cont = ServeEngine(model, params, batch_slots=2, max_len=32,
                       flush_interval=2)
    out_c = cont.run(_reqs(cfg, lens, max_new))
    assert set(out_c) == set(out_f) == {0, 1, 2, 3}
    for rid in out_f:
        assert out_c[rid] == out_f[rid], rid
        assert len(out_c[rid]) == max_new[rid]
    # continuous reuses ONE persistent cache; fixed re-inits per batch
    assert cont.requests_served == 4
    assert cont.tokens_emitted == sum(max_new)


def test_masked_slots_do_no_phantom_work():
    """Dummy (inactive) slots must not leak tokens into results."""
    from repro.serve import FixedBatchEngine, ServeEngine
    cfg, model, params = _setup()
    # 3 requests on 2 fixed slots -> second batch has a dummy row
    fixed = FixedBatchEngine(model, params, batch_slots=2, max_len=32)
    out = fixed.run(_reqs(cfg, [4, 4, 4], [3, 3, 3]))
    assert set(out) == {0, 1, 2}
    assert fixed.requests_served == 3
    assert fixed.tokens_emitted == 9
    # continuous: a single request on 4 slots (3 masked the whole run)
    cont = ServeEngine(model, params, batch_slots=4, max_len=32)
    out_c = cont.run(_reqs(cfg, [4], [3]))
    assert set(out_c) == {0} and len(out_c[0]) == 3


# ---------------------------------------------------------------------------
# scheduler: admission/eviction ordering + slot-scoped tracing
# ---------------------------------------------------------------------------

def test_admission_eviction_ordering():
    from repro.serve import ServeEngine
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, batch_slots=2, max_len=64,
                         flush_interval=2)
    # r0 is long; r1..r3 are short and must rotate through slot 1 while
    # r0 keeps decoding (no head-of-line blocking)
    reqs = _reqs(cfg, [4, 4, 4, 4], [20, 2, 2, 2])
    out = engine.run(reqs)
    assert sorted(out) == [0, 1, 2, 3]
    # FIFO admission order
    adm = [s for s in engine.segments if s.kind == "prefill"]
    assert [s.rids[0] for s in adm] == [0, 1, 2, 3]
    # mid-decode admission: some decode segment pairs r0 with a request
    # admitted AFTER an earlier one was evicted
    joint = [set(s.rids) for s in engine.segments if s.kind == "decode"
             and len(s.rids) > 1]
    assert any({0, 2} <= j or {0, 3} <= j for j in joint), joint
    # eviction frees the slot before the next admission reuses it
    by_rid = {r.rid: r for r in reqs}
    assert by_rid[1].t_done <= by_rid[2].t_admitted
    assert by_rid[2].t_done <= by_rid[3].t_admitted
    # slot-scoped depth-1 regions: slot 0 only ever runs r0's decode
    slot0 = engine.tracer.phases(depth=1, name="decode", slot=0)
    slot1 = engine.tracer.phases(depth=1, name="decode", slot=1)
    assert slot0 and slot1
    ev_steps = {e.step for e in engine.tracer.events
                if e.depth == 1 and e.slot == 1}
    assert ev_steps >= {1, 2, 3}
    # the slot-segment schedule tiles the depth-0 phases EXACTLY
    # (bit-identical boundaries -> conservation by construction)
    ph = sorted((a, b) for _, a, b in engine.tracer.phases(depth=0))
    sg = sorted((s.t_lo, s.t_hi) for s in engine.segments)
    assert ph == sg
    # trace array export carries the slot column
    arrs = engine.tracer.to_arrays()
    assert "slot" in arrs and set(np.unique(arrs["slot"])) <= {-1, 0, 1}


def test_arrival_respecting_run_completes():
    from repro.serve import ServeEngine, poisson_requests
    cfg, model, params = _setup()
    reqs = poisson_requests(5, rate_rps=2000.0, seed=3,
                            prompt_lens=(4, 6), new_tokens=(1, 4),
                            vocab_size=cfg.vocab_size)
    engine = ServeEngine(model, params, batch_slots=2, max_len=32,
                         flush_interval=2)
    out = engine.run(reqs, respect_arrivals=True)
    assert sorted(out) == list(range(5))
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens
        assert r.t_first >= r.t_arrival or math.isnan(r.t_first)
        assert r.ttft_s >= 0.0


def test_zero_budget_request_completes_empty():
    from repro.serve import ServeEngine
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, [4, 4], [0, 2])
    out = engine.run(reqs)
    assert out[0] == [] and len(out[1]) == 2


# ---------------------------------------------------------------------------
# host-sync regression: device-side token buffers, counted drains
# ---------------------------------------------------------------------------

def test_host_transfer_counts():
    from repro.serve import FixedBatchEngine, ServeEngine
    cfg, model, params = _setup()
    # fixed engine: 20 decode tokens at flush=8 -> ceil(20/8)=3 drains
    fixed = FixedBatchEngine(model, params, batch_slots=2, max_len=64,
                             flush_interval=8)
    fixed.run(_reqs(cfg, [4, 4], [20, 20]))
    assert fixed.host_transfers == 3
    # continuous: 1 pending prefill token + 32 decode steps at flush=16
    # -> exactly 2 segment drains, NOT one transfer per token
    cont = ServeEngine(model, params, batch_slots=2, max_len=64,
                       flush_interval=16)
    cont.run(_reqs(cfg, [4], [33]))
    assert cont.host_transfers == 2
    assert cont.tokens_emitted == 33
    assert cont.host_transfers < cont.tokens_emitted // 4


# ---------------------------------------------------------------------------
# per-request energy: conservation, registry gauges, JSONL artifact
# ---------------------------------------------------------------------------

def _serve_fabric(engine, lead=0.05, n_chips=2, seed=0):
    """Synthesize a sensor fabric whose truth follows the engine's
    recorded phases (the serve_demo idiom)."""
    from repro.core import NodeFabric, ToolSpec, phase_power
    from repro.core.measurement_model import CHIP_IDLE_W
    from repro.core.power_model import occupancy_power
    occ = {"admission": (0.0, 0.05, 0.0), "prefill": (1.0, 0.5, 0.1),
           "decode": (0.15, 1.0, 0.1)}
    shifted = [(n, a + lead, b + lead)
               for n, a, b in engine.tracer.phases(depth=0)]
    watts = {n: {"watts": occupancy_power(*occ.get(n, (0, 0.1, 0)))}
             for n, _, _ in shifted}
    truth = phase_power([("__lead__", 0.0, lead)] + shifted,
                        {**watts, "__lead__": {"watts": CHIP_IDLE_W}})
    fabric = NodeFabric(chip_truths=[truth] * n_chips)
    return fabric.sample_all(ToolSpec(), seed=seed)


def test_per_request_energy_conserves(tmp_path, monkeypatch):
    from repro.health import HealthRegistry
    from repro.serve import METER_LOG_ENV, ServeEngine
    cfg, model, params = _setup()
    reg = HealthRegistry()
    engine = ServeEngine(model, params, batch_slots=2, max_len=64,
                         flush_interval=4, registry=reg)
    reqs = _reqs(cfg, [4, 8, 6], [10, 3, 6], seed=1)
    for i, r in enumerate(reqs):
        r.user = f"user{i % 2}"
    engine.run(reqs)
    lead = 0.05
    traces = _serve_fabric(engine, lead=lead)
    with monkeypatch.context() as m:
        m.setenv(METER_LOG_ENV, str(tmp_path))
        report = engine.attribute_requests(traces, t_shift=lead,
                                           track=False)
    # every request billed, energies positive, J/token consistent
    assert sorted(r.rid for r in report.requests) == [0, 1, 2]
    for r in report.requests:
        assert r.energy_j > 0.0
        assert r.tokens == len(reqs[r.rid].prompt) + reqs[r.rid].max_new_tokens
        assert r.j_per_token == pytest.approx(r.energy_j / r.tokens)
        assert r.ttft_s >= 0.0 and r.latency_s >= r.ttft_s
    # conservation: per-request energies sum to the fused PHASE totals
    fused = engine.attribute_phases(traces, t_shift=lead, fuse=True,
                                    streaming=True, track=False)
    phase_totals = np.asarray([[p.energy_j for p in row]
                               for row in fused.values()])
    assert report.conservation_rel_err(phase_totals) <= 1e-5
    # ... and to the metering stage's own segment totals exactly-ish
    assert report.conservation_rel_err(report.segment_totals) <= 1e-9
    # per-user aggregation partitions the total
    pu = report.per_user()
    assert set(pu) == {"user0", "user1"}
    assert sum(u["energy_j"] for u in pu.values()) == \
        pytest.approx(report.total_j)
    assert report.percentiles()["j_per_request"]["p50"] > 0.0
    # registry export: scheduler counters + rolling metering gauges
    snap = reg.json_snapshot()
    assert snap["serve_requests_total"] == 3.0
    assert snap["serve_host_transfers_total"] >= 1.0
    assert snap["meter_j_per_request"]["p50"] > 0.0
    assert "repro_meter_j_per_request" in reg.prometheus_text()
    # JSONL artifact trail (the CI per-request metering artifact)
    files = list(tmp_path.glob("request-energies-*.jsonl"))
    assert len(files) == 1
    lines = [json.loads(ln) for ln in
             files[0].read_text().strip().splitlines()]
    assert [ln["rid"] for ln in lines] == [0, 1, 2]
    assert all(ln["energy_j"] > 0.0 for ln in lines)
    # re-attribution is bit-identical (outside the monkeypatch scope,
    # so in CI this run feeds the ambient REPRO_METER_LOG_DIR artifact)
    again = engine.attribute_requests(traces, t_shift=lead, track=False)
    for r1, r2 in zip(report.requests, again.requests):
        assert r1.energy_by_device == r2.energy_by_device, r1.rid


def test_metering_deterministic_under_permutation():
    """Bit-identical per-request energies under slot-assignment
    permutations: segment list order and within-segment rid order."""
    from repro.align import group_traces_by_device
    from repro.core import NodeFabric, ToolSpec, square_wave
    from repro.fleet.pipeline import (SlotSegment,
                                      attribute_energy_fused_streaming)
    truth = square_wave(1.0, 2, lead_s=0.5, tail_s=0.5)
    traces = NodeFabric(chip_truths=[truth] * 2).sample_all(
        ToolSpec(), seed=0)
    groups = list(group_traces_by_device(traces).values())
    phases = [("work", 0.5, 1.2), ("work", 1.2, 2.0)]
    segs_a = [SlotSegment(0.5, 1.2, (0, 1, 2), (3.0, 1.0, 2.0)),
              SlotSegment(1.2, 2.0, (1, 2), (2.0, 5.0))]
    segs_b = [SlotSegment(1.2, 2.0, (2, 1), (5.0, 2.0)),
              SlotSegment(0.5, 1.2, (2, 0, 1), (2.0, 3.0, 1.0))]
    out = {}
    for key, segs in (("a", segs_a), ("b", segs_b)):
        _, pipe = attribute_energy_fused_streaming(
            groups, phases, meter=segs, track=False, return_pipe=True)
        out[key] = pipe.request_energies()
    assert sorted(out["a"]) == sorted(out["b"]) == [0, 1, 2]
    for rid in out["a"]:
        assert np.array_equal(out["a"][rid], out["b"][rid]), rid
    # shares conserve: requests sum to segment totals
    tot = np.sum([out["a"][r] for r in out["a"]], axis=0)
    seg_tot = pipe.meter_stage.segment_totals().sum(axis=1)
    np.testing.assert_allclose(tot, seg_tot, rtol=1e-12)


# ---------------------------------------------------------------------------
# load generator
# ---------------------------------------------------------------------------

def test_poisson_loadgen_seeded_and_shaped():
    from repro.serve import poisson_requests
    a = poisson_requests(40, rate_rps=100.0, seed=7)
    b = poisson_requests(40, rate_rps=100.0, seed=7)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert [r.max_new_tokens for r in a] == [r.max_new_tokens for r in b]
    assert [r.user for r in a] == [r.user for r in b]
    arr = [r.arrival_s for r in a]
    assert all(t2 > t1 for t1, t2 in zip(arr, arr[1:]))
    assert {len(r.prompt) for r in a} <= {4, 8, 12}
    assert all(1 <= r.max_new_tokens <= 32 for r in a)
    # bimodal budgets: both short and long modes show up
    assert min(r.max_new_tokens for r in a) <= 11
    assert max(r.max_new_tokens for r in a) >= 22
    c = poisson_requests(40, rate_rps=100.0, seed=8)
    assert [r.arrival_s for r in c] != [r.arrival_s for r in a]
