"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import Model
from repro.train.instrumented import (attribution_report,
                                      run_instrumented_training)
from repro.train.loop import make_train_step
from repro.train.optimizer import optimizer_for, schedule_for


def _setup(arch="llama3.2-3b", batch=4, seq=64):
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = optimizer_for(cfg)
    state = {"params": params, "opt": opt.init(params)}
    lr = schedule_for(cfg.name, 3e-3, 500)
    step_fn = jax.jit(make_train_step(model, opt, lr))
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=0))
    return cfg, model, state, step_fn, data


def test_training_reduces_loss():
    cfg, model, state, step_fn, data = _setup()
    losses = []
    p, o = state["params"], state["opt"]
    for s in range(25):
        b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        p, o, m = step_fn(p, o, b, jnp.asarray(s, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]


def test_instrumented_run_attributes_energy():
    """Full pipeline: real train loop -> traced phases -> synthesized
    sensors -> ΔE/Δt attribution.  train_step must dominate energy and the
    attributed power must sit between idle and TDP."""
    cfg, model, state, step_fn, data = _setup(batch=2, seq=32)
    p, o = state["params"], state["opt"]

    def next_batch(step):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    def train_one(st, batch, step):
        pp, oo = st if st is not None else (p, o)
        pp, oo, m = step_fn(pp, oo, batch, jnp.asarray(step, jnp.int32))
        return (pp, oo), m

    run, _ = run_instrumented_training(train_one, 8, next_batch)
    by_name, per_phase = attribution_report(run)
    assert "train_step" in by_name
    total = sum(v["energy_j"] for v in by_name.values())
    assert by_name["train_step"]["energy_j"] > 0.5 * total
    pw = by_name["train_step"]["mean_power_w"]
    assert 55.0 - 5 < pw < 215.0 + 5
    # microbench: every traced phase got a PhaseEnergy record
    assert len(per_phase) == len(run.phases)


def test_grad_compression_hook_trains():
    from repro.distributed.compression import make_grad_hook
    cfg, model, state, _, data = _setup()
    opt = optimizer_for(cfg)
    lr = schedule_for(cfg.name, 3e-3, 500)
    step_fn = jax.jit(make_train_step(model, opt, lr,
                                      grad_hook=make_grad_hook("bf16")))
    p, o = state["params"], state["opt"]
    losses = []
    for s in range(15):
        b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        p, o, m = step_fn(p, o, b, jnp.asarray(s, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_microbatched_step_matches_full_batch():
    cfg, model, state, _, data = _setup(batch=4, seq=32)
    opt = optimizer_for(cfg)
    lr = schedule_for(cfg.name, 1e-3, 500)
    f1 = jax.jit(make_train_step(model, opt, lr, micro=1))
    f2 = jax.jit(make_train_step(model, opt, lr, micro=2))
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    p, o = state["params"], state["opt"]
    p1, _, m1 = f1(p, o, b, jnp.asarray(0, jnp.int32))
    p2, _, m2 = f2(p, o, b, jnp.asarray(0, jnp.int32))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_hpl_phases_and_mixed_precision_story():
    from repro.hpl import (hpl_mxp_solve, hpl_solve, make_dd_system,
                           make_system)
    a, b, _ = make_system(128)
    _, full = hpl_solve(a, b, nb=32)
    assert full["residual"] < 1e-4
    names = [e.name for e in full["tracer"].events]
    assert {"hpl_factorize", "hpl_solve", "hpl_verify"} <= set(names)
    ad, bd, _ = make_dd_system(128)
    _, mxp = hpl_mxp_solve(ad, bd, nb=32)
    assert mxp["residual"] < 1e-4


def test_wsd_schedule_shape():
    from repro.train.optimizer import wsd_schedule
    lr = wsd_schedule(base_lr=1.0, warmup=10, stable=80, decay=10)
    assert float(lr(0)) < 0.2
    assert abs(float(lr(50)) - 1.0) < 1e-6       # stable plateau
    assert float(lr(99)) < 0.7                   # decaying
    assert float(lr(150)) <= 0.011               # fully decayed


def test_optimizers_minimize_quadratic():
    from repro.train.optimizer import adafactor, adamw
    for opt in (adamw(weight_decay=0.0), adafactor()):
        params = {"w": jnp.asarray(np.full((4, 4), 5.0), jnp.float32)}
        state = opt.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp p^2
            params, state, _ = opt.update(grads, state, params, 0.05)
        assert float(jnp.abs(params["w"]).max()) < 1.0
