"""The collective wire format: codec primitives + reduce frames.

Covers the lossless framing behind ``HostCollectives.allreduce_framed``:
integer codecs round-trip exactly (empty / single-element / constant /
adversarial-magnitude inputs), frames decode to bit-identical float64
payloads, the left fold over decoded frames equals the fold over the
originals, and no frame can ever hit the jaxlib 0.4.x 1-byte KV-store
segfault (``blocking_key_value_get_bytes`` crashes on 1-byte values —
see ROADMAP).  Property tests ride hypothesis when it is installed.
"""
import numpy as np
import pytest

from repro.core.trace_format import (bitpack, bitunpack, delta_decode,
                                     delta_encode, varint_decode,
                                     varint_encode, zigzag_decode,
                                     zigzag_encode)
from repro.distributed.compression import (MIN_FRAME_BYTES,
                                           decode_reduce_frame,
                                           encode_reduce_frame, WireStats)
from repro.distributed.multihost import ThreadCollectives


# ---------------------------------------------------------------------------
# codec primitives
# ---------------------------------------------------------------------------

INT_CASES = [
    np.asarray([], np.int64),                       # empty
    np.asarray([0], np.int64),                      # single element
    np.asarray([7] * 13, np.int64),                 # constant
    np.asarray([-1, 1, -2, 2, 0], np.int64),        # sign-alternating
    np.asarray([2**62, -(2**62), 2**63 - 1, -(2**63)], np.int64),
]


@pytest.mark.parametrize("v", INT_CASES, ids=range(len(INT_CASES)))
def test_zigzag_roundtrip(v):
    np.testing.assert_array_equal(zigzag_decode(zigzag_encode(v)), v)


def test_zigzag_mapping():
    # the standard interleave: small magnitudes stay small either way
    got = zigzag_encode([0, -1, 1, -2, 2])
    np.testing.assert_array_equal(got, np.asarray([0, 1, 2, 3, 4],
                                                  np.uint64))


@pytest.mark.parametrize("v", INT_CASES, ids=range(len(INT_CASES)))
def test_delta_roundtrip(v):
    np.testing.assert_array_equal(delta_decode(delta_encode(v)), v)


def test_delta_constant_is_mostly_zero():
    d = delta_encode(np.full(40, 1234, np.int64))
    assert d[0] == 1234 and not d[1:].any()


@pytest.mark.parametrize("n", [0, 1, 127, 128, 300, 2**31, 2**63])
def test_varint_roundtrip(n):
    buf = varint_encode(n)
    val, off = varint_decode(buf)
    assert (val, off) == (n, len(buf))


def test_varint_truncation_raises():
    buf = varint_encode(2**31)
    with pytest.raises(ValueError):
        varint_decode(buf[:-1])


@pytest.mark.parametrize("bits", [0, 1, 3, 7, 13, 32, 63, 64])
def test_bitpack_roundtrip(bits):
    rng = np.random.default_rng(bits)
    if bits == 0:
        v = np.zeros(17, np.uint64)
    elif bits == 64:
        v = rng.integers(0, 2**63, 17).astype(np.uint64) * 2 + 1
    else:
        v = rng.integers(0, 2**bits, 17).astype(np.uint64)
    np.testing.assert_array_equal(bitunpack(bitpack(v, bits), bits, 17), v)


def test_bitpack_empty_and_overflow():
    assert bitpack(np.asarray([], np.uint64), 5) == b""
    np.testing.assert_array_equal(bitunpack(b"", 5, 0),
                                  np.zeros(0, np.uint64))
    with pytest.raises(ValueError):
        bitpack(np.asarray([8], np.uint64), 3)   # 8 needs 4 bits
    with pytest.raises(ValueError):
        bitpack(np.asarray([1], np.uint64), 0)   # bits=0 must be all-zero
    with pytest.raises(ValueError):
        bitunpack(b"\x01", 13, 5)                # truncated block


# ---------------------------------------------------------------------------
# reduce frames
# ---------------------------------------------------------------------------

FRAME_CASES = [
    (0.0, np.asarray([], np.float64)),                 # empty vector
    (-1.5, np.asarray([3.25], np.float64)),            # single element
    (2.0, np.zeros(64, np.float64)),                   # all-zero (no hop)
    (0.5, np.full(9, 7.75, np.float64)),               # constant dense
    (np.inf, np.asarray([0.0, -0.125, 0.0, 5e-324, 1e308, 0.0])),
    (-np.inf, np.linspace(-1e9, 1e9, 33)),             # fully dense
]


@pytest.mark.parametrize("scalar,vec", FRAME_CASES,
                         ids=range(len(FRAME_CASES)))
def test_frame_roundtrip_exact(scalar, vec):
    s, v = decode_reduce_frame(encode_reduce_frame(scalar, vec))
    # scalar must be uncompressed-exact, including ±inf sentinels
    np.testing.assert_array_equal(np.float64(s), np.float64(scalar))
    assert v.dtype == np.float64 and v.shape == vec.shape
    # every surviving float bit-exact (zeros may lose their sign)
    np.testing.assert_array_equal(v, np.where(vec == 0.0, 0.0, vec))


def test_frame_nan_payload_bit_exact():
    vec = np.asarray([0.0, np.nan, -np.nan, 1.0])
    _, v = decode_reduce_frame(encode_reduce_frame(0.0, vec))
    np.testing.assert_array_equal(v.view(np.uint64)[1:3],
                                  vec.view(np.uint64)[1:3])


def test_frame_sparse_beats_dense():
    v = np.zeros(256, np.float64)
    v[::16] = np.pi
    frame = encode_reduce_frame(1.0, v)
    assert len(frame) < 8 * (1 + v.size) / 10     # the >=10x target
    _, out = decode_reduce_frame(frame)
    np.testing.assert_array_equal(out, v)


def test_frame_dense_fallback_bounded():
    rng = np.random.default_rng(0)
    v = rng.standard_normal(128)                  # fully dense
    frame = encode_reduce_frame(0.0, v)
    assert len(frame) <= MIN_FRAME_BYTES + 3 + 8 * v.size
    _, out = decode_reduce_frame(frame)
    np.testing.assert_array_equal(out, v)


def test_frame_never_one_byte():
    """jaxlib 0.4.x blocking_key_value_get_bytes segfaults on 1-byte KV
    values; every frame must stay well clear of that."""
    assert MIN_FRAME_BYTES >= 2
    for scalar, vec in FRAME_CASES:
        assert len(encode_reduce_frame(scalar, vec)) >= MIN_FRAME_BYTES


@pytest.mark.parametrize("mutate", [
    lambda b: b[:1],                               # truncated header
    lambda b: b"XX" + b[2:],                       # bad magic
    lambda b: b[:2] + b"\x09" + b[3:],             # unknown version
    lambda b: b[:-3],                              # truncated values
])
def test_frame_corruption_raises(mutate):
    frame = encode_reduce_frame(1.0, np.arange(8, dtype=np.float64))
    with pytest.raises(ValueError):
        decode_reduce_frame(mutate(frame))


def test_wire_stats_ratio():
    ws = WireStats()
    assert ws.ratio == 0.0 or ws.payload_bytes == 0
    ws.record(20, 400)
    ws.record(15, 400)
    assert ws.frames == 2 and ws.payload_bytes == 35
    assert ws.ratio == pytest.approx(800 / 35)


# ---------------------------------------------------------------------------
# fold equivalence through real collectives
# ---------------------------------------------------------------------------

def test_framed_fold_matches_dense_fold():
    """allreduce_framed over the wire format == the dense left fold."""
    rng = np.random.default_rng(7)
    n, procs = 48, 4
    vecs = []
    for p in range(procs):
        v = np.zeros(n, np.float64)
        rows = rng.choice(n, size=6, replace=False)
        v[rows] = rng.standard_normal(6) * 10.0 ** rng.integers(-6, 7, 6)
        vecs.append(v)
    scalars = [3.0, -1.0, 2.5, -1.0]

    expected_s = min(scalars)
    expected_v = vecs[0].copy()
    for v in vecs[1:]:
        expected_v = expected_v + v                # left fold in id order

    group = ThreadCollectives(procs)
    parts = [group.participant(p) for p in range(procs)]
    import threading
    results = [None] * procs

    def worker(pid):
        results[pid] = parts[pid].allreduce_framed(scalars[pid],
                                                   vecs[pid])

    threads = [threading.Thread(target=worker, args=(p,))
               for p in range(procs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for pid, (s, v) in enumerate(results):
        assert s == expected_s
        np.testing.assert_array_equal(v, expected_v)  # bit-identical
        ws = parts[pid].wire_stats
        assert ws.frames == 1
        assert ws.raw_bytes == 8 * (1 + n)
        assert ws.payload_bytes < ws.raw_bytes / 4
